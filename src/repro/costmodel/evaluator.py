"""Schedule evaluation: layerwise baseline vs fused states (paper Alg. 1 l.5-9).

A :class:`FusionState` is costed group-by-group.  Because a tensor's DRAM
residency is fully determined by its producer's group membership (it goes
off-chip iff some consumer is outside the group), each group's cost depends
*only* on its member set — so group costs are memoized across the entire GA
run, which is what makes the paper's P=100 x G=500 search fast.

Group costing (multi-member groups):
  1. largest output-tile height ``t`` whose line-buffer footprint fits the
     activation buffer (``repro.core.receptive``); no feasible ``t`` =>
     the state is invalid (paper: "Any mapping where intermediate storage
     exceeds capacity is discarded as invalid").
  2. if aggregate group weights exceed the weight buffer, weights re-stream
     from DRAM once per tile pass (paper §IV).
  3. member layers are costed with intra-group edges kept on-chip; compute
     and DRAM time overlap within the group.

Hot-path notes (incremental engine): for bitmask genomes the group cache is
keyed by the group's **member node-bitmask** (a Python int — one machine-word
hash instead of a frozenset of strings), member topological order comes from
integer adjacency, and :meth:`Evaluator.fitness_batch` dedupes an entire
offspring generation against the cache before costing only novel groups.
Reference states (``repro.core.fusion_ref``) take the original frozenset-keyed
path; both paths run the same float operations in the same order, so costs
agree bit-for-bit (pinned by ``tests/test_fusion_equivalence.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple, Union

from repro.core.fusion import FusionState, iter_bits
from repro.core.graph import LayerGraph
from repro.core.receptive import max_tile_rows
from repro.core.toposort import member_order_ids, topological_sort_edges
from repro.costmodel.accelerator import Accelerator
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel
from repro.costmodel.mapper import LayerCost, map_layer

_MISSING = object()

#: objectives the evaluator scores natively (ScheduleCost.metric and the
#: batched fitness hot path); repro.search registers exactly these as
#: built-ins and routes anything else through the generic evaluate() path
NATIVE_OBJECTIVES = ("edp", "energy", "cycles", "dram")


@dataclass(frozen=True)
class ScheduleCost:
    energy_pj: float
    cycles: float
    dram_read_words: int
    dram_write_words: int
    act_write_events: int
    macs: int
    n_groups: int
    clock_hz: float = 200e6      # threaded from Accelerator.clock_mhz

    @property
    def seconds(self) -> float:
        return self.cycles / self.clock_hz

    @property
    def edp(self) -> float:
        return self.energy_pj * self.cycles

    @property
    def energy_j(self) -> float:
        return self.energy_pj * 1e-12

    def metric(self, objective: str) -> float:
        return {"edp": self.edp, "energy": self.energy_pj,
                "cycles": self.cycles,
                "dram": float(self.dram_read_words + self.dram_write_words),
                }[objective]


GroupKey = Union[int, FrozenSet[str]]

# group cost record: (energy_pj, cycles, dram_read, dram_write,
#                     act_write_events, macs) — or None if over-capacity
GroupCost = Optional[Tuple[float, float, int, int, int, int]]


class Evaluator:
    """Memoizing schedule evaluator for one (graph, accelerator) pair."""

    def __init__(self, graph: LayerGraph, acc: Accelerator,
                 em: EnergyModel = DEFAULT_ENERGY):
        self.graph = graph
        self.acc = acc
        self.em = em
        self.cg = graph.compiled()
        self.clock_hz = acc.clock_mhz * 1e6
        self._group_cache: Dict[GroupKey, GroupCost] = {}
        # multi-member group mask -> cost delta vs its members' singleton
        # costs (the fast fitness path sums base + these corrections)
        self._corr: Dict[int, GroupCost] = {}
        # genome mask -> scalar cost sums (None = invalid/unschedulable);
        # lets offspring apply only their mutation's group delta
        self._sums: Dict[int, Optional[tuple]] = {}
        # layerwise scalar sums + per-objective baseline metrics (lazy)
        self._base: Optional[tuple] = None
        self.evals = 0
        self.group_hits = 0          # group-cost lookups served from cache
        self.group_misses = 0        # novel groups actually costed
        self.sums_hits = 0           # states served via parent-delta sums
        self.batch_states = 0        # states seen by fitness_batch
        self.batch_unique = 0        # ... of which had a novel genome
        self._layerwise: Optional[ScheduleCost] = None

    # ---- public API ----------------------------------------------------------------
    def layerwise(self) -> ScheduleCost:
        if self._layerwise is None:
            self._layerwise = self.evaluate(FusionState.layerwise(self.graph))
            assert self._layerwise is not None
        return self._layerwise

    def evaluate(self, state) -> Optional[ScheduleCost]:
        """Total cost, or None if the state is invalid (unschedulable or
        over-capacity).  Accepts bitmask states (fast path) and reference
        states (frozenset path)."""
        self.evals += 1
        if not state.is_schedulable():
            return None
        if hasattr(state, "group_masks"):
            return self._evaluate_keys(state.group_masks())
        return self._evaluate_keys(state.groups())

    def fitness(self, state, objective: str = "edp") -> float:
        """Paper Alg. 1 line 9: F = Eval_layerwise / Eval_new (0 if invalid)."""
        cost = self.evaluate(state)
        if cost is None:
            return 0.0
        new = cost.metric(objective)
        return self.layerwise().metric(objective) / new if new > 0 else 0.0

    def fitness_batch(self, states: Sequence[FusionState],
                      objective: str = "edp") -> List[float]:
        """Fitness for a whole offspring generation (GA hot path).

        Dedupes the generation by genome against the mask-keyed caches before
        costing, so duplicate offspring and shared groups never re-enter the
        cost model; per-state cost is assembled as the layerwise baseline plus
        cached corrections from multi-member groups only (singleton groups —
        the vast majority — contribute exactly their baseline cost, so they
        are skipped).  Values may differ from :meth:`fitness` by float
        re-association only (~1 ulp); selection order is unaffected in
        practice and ``run_ga`` re-scores its final winner exactly.
        """
        self.batch_states += len(states)
        uniq: Dict[int, float] = {}
        out: List[float] = []
        for s in states:
            k = s.key()
            f = uniq.get(k)
            if f is None:
                f = self._fitness_fast(s, objective)
                uniq[k] = f
            out.append(f)
        self.batch_unique += len(uniq)
        return out

    def _fitness_fast(self, state: FusionState, objective: str) -> float:
        """Baseline-plus-corrections fitness for bitmask states.

        When the state carries a mutation delta and its parent's cost sums
        are cached, only the removed/added groups are (un)applied — O(1) per
        offspring; otherwise the sums are rebuilt from the layerwise baseline
        plus every multi-member group's cached correction.
        """
        sched = state._sched                 # inlined is_schedulable (hot path)
        if sched is None:
            sched = state.is_schedulable()
        if not sched:
            self._sums[state.mask] = None
            return 0.0
        if self._base is None:
            lw = self.layerwise()
            self._base = (lw.energy_pj, lw.cycles, lw.dram_read_words,
                          lw.dram_write_words, lw.act_write_events, lw.macs,
                          {obj: lw.metric(obj)
                           for obj in ("edp", "energy", "cycles", "dram")})
        corr = self._corr
        corr_get = corr.get
        hits = 0
        sums = None
        delta = state._delta
        if delta is not None:
            psums = self._sums.get(delta[0])
            if psums is not None:            # parent scored and valid
                e, c, dr, dw, aw, mc = psums
                ok = True
                for gm in delta[1]:          # groups dissolved by the mutation
                    d = corr_get(gm, _MISSING)
                    if d is _MISSING or d is None:
                        ok = False           # defensive: rebuild from scratch
                        break
                    hits += 1
                    e -= d[0]
                    c -= d[1]
                    dr -= d[2]
                    dw -= d[3]
                    aw -= d[4]
                    mc -= d[5]
                if ok:
                    self.sums_hits += 1
                    for gm in delta[2]:      # groups created by the mutation
                        d = corr_get(gm, _MISSING)
                        if d is _MISSING:
                            d = self._compute_correction(gm)
                            corr[gm] = d
                        else:
                            hits += 1
                        if d is None:        # over-capacity group: invalid
                            self.group_hits += hits
                            self._sums[state.mask] = None
                            return 0.0
                        e += d[0]
                        c += d[1]
                        dr += d[2]
                        dw += d[3]
                        aw += d[4]
                        mc += d[5]
                    sums = (e, c, dr, dw, aw, mc)
        if sums is None:                     # no usable lineage: full rebuild
            e, c, dr, dw, aw, mc = self._base[:6]
            mgroups = state._mgroups         # inlined multi_masks (hot path)
            if mgroups is None:
                mgroups = state.multi_masks()
            for gm in mgroups:               # singletons cost their baseline
                d = corr_get(gm, _MISSING)
                if d is _MISSING:
                    d = self._compute_correction(gm)
                    corr[gm] = d
                else:
                    hits += 1
                if d is None:
                    self.group_hits += hits
                    self._sums[state.mask] = None
                    return 0.0               # over-capacity group: invalid
                e += d[0]
                c += d[1]
                dr += d[2]
                dw += d[3]
                aw += d[4]
                mc += d[5]
            sums = (e, c, dr, dw, aw, mc)
        self.group_hits += hits
        self._sums[state.mask] = sums
        e, c, dr, dw = sums[0], sums[1], sums[2], sums[3]
        if objective == "edp":
            new = e * c
        elif objective == "energy":
            new = e
        elif objective == "cycles":
            new = c
        else:
            new = float(dr + dw)
        return self._base[6][objective] / new if new > 0 else 0.0

    def _compute_correction(self, gmask: int) -> GroupCost:
        """Cost delta of fusing ``gmask``'s members vs leaving each layerwise."""
        g = self._group_cost(gmask)
        if g is None:
            return None
        e, c, dr, dw, aw, mc = g
        for i in iter_bits(gmask):
            s = self._group_cost(1 << i)
            e -= s[0]
            c -= s[1]
            dr -= s[2]
            dw -= s[3]
            aw -= s[4]
            mc -= s[5]
        return (e, c, dr, dw, aw, mc)

    def _group_cost(self, key: GroupKey) -> GroupCost:
        cached = self._group_cache.get(key, _MISSING)
        if cached is _MISSING:
            cached = (self._compute_group_cost_mask(key)
                      if isinstance(key, int)
                      else self._compute_group_cost_members(key))
            self._group_cache[key] = cached
            self.group_misses += 1
        else:
            self.group_hits += 1
        return cached

    def cache_stats(self) -> Dict[str, float]:
        """Cache-effectiveness counters.  ``group_hit_rate`` covers explicit
        group-cost lookups only; on the GA hot path most states are served by
        the parent-delta sums instead (no group lookups at all), which
        ``delta_hit_rate`` reports — that is the headline number for batch
        evaluation effectiveness."""
        touches = self.group_hits + self.group_misses
        return {
            "unique_groups": len(self._group_cache),
            "group_hits": self.group_hits,
            "group_misses": self.group_misses,
            "group_hit_rate": self.group_hits / touches if touches else 0.0,
            "sums_hits": self.sums_hits,
            "delta_hit_rate": (self.sums_hits / self.batch_unique
                               if self.batch_unique else 0.0),
            "states_evaluated": self.evals,
            "batch_states": self.batch_states,
            "batch_unique": self.batch_unique,
        }

    # ---- internals ------------------------------------------------------------------
    def _evaluate_keys(self, keys: Sequence[GroupKey]
                       ) -> Optional[ScheduleCost]:
        e = 0.0
        c = 0.0
        dr = dw = aw = mc = 0
        for key in keys:
            g = self._group_cost(key)
            if g is None:
                return None
            e += g[0]
            c += g[1]
            dr += g[2]
            dw += g[3]
            aw += g[4]
            mc += g[5]
        return ScheduleCost(
            energy_pj=e, cycles=c, dram_read_words=dr, dram_write_words=dw,
            act_write_events=aw, macs=mc, n_groups=len(keys),
            clock_hz=self.clock_hz)

    def _compute_group_cost_mask(self, gmask: int) -> GroupCost:
        """Fast path: members given as a node bitmask, order and membership
        tests all on integers."""
        cg = self.cg
        order = member_order_ids(cg.succ_ids, list(iter_bits(gmask)))
        multi = sum(1 for i in order if cg.macs[i]) > 1

        weight_passes = 1
        if multi and len(order) > 1:
            names_order = [cg.names[i] for i in order]
            t = max_tile_rows(self.graph, names_order, self.acc.act_buf_words)
            if t == 0:
                return None                              # over-capacity: invalid
            group_w = sum(cg.weight_size[i] for i in order)
            if group_w > self.acc.weight_buf_words:
                sink_p = max((cg.p[i] or 1) for i in order)
                weight_passes = math.ceil(sink_p / t)

        total = LayerCost()
        compute_cycles = 0.0
        dram_cycles = 0.0
        for i in order:
            preds = cg.pred_ids[i]
            inputs_off = (not preds) or \
                any(not (gmask >> p) & 1 for p in preds)
            succs = cg.succ_ids[i]
            outputs_off = (not succs) or \
                any(not (gmask >> v) & 1 for v in succs)
            lc = map_layer(cg.layers[i], self.acc, self.em,
                           inputs_offchip=inputs_off,
                           outputs_offchip=outputs_off,
                           weight_stream_passes=weight_passes if multi else 1)
            total += lc
            compute_cycles += lc.compute_cycles
            dram_cycles += lc.dram_cycles
        # compute/DRAM overlap across the whole group pipeline
        return (total.energy_pj, max(compute_cycles, dram_cycles),
                total.dram_read_words, total.dram_write_words,
                total.act_write_events, total.macs)

    def _compute_group_cost_members(self, members: FrozenSet[str]
                                    ) -> GroupCost:
        """Reference path: members as a frozenset of layer names (used by
        ``ReferenceFusionState``; kept operation-for-operation identical to
        the fast path so both produce bit-equal costs)."""
        g = self.graph
        order = topological_sort_edges(
            [n for n in g.names if n in members], g.edges)
        multi = len([n for n in order if g.layers[n].macs]) > 1

        weight_passes = 1
        if multi and len(order) > 1:
            t = max_tile_rows(g, order, self.acc.act_buf_words)
            if t == 0:
                return None                              # over-capacity: invalid
            group_w = sum(g.layers[n].weight_size for n in order)
            if group_w > self.acc.weight_buf_words:
                sink_p = max((g.layers[n].p or 1) for n in order)
                weight_passes = math.ceil(sink_p / t)

        total = LayerCost()
        compute_cycles = 0.0
        dram_cycles = 0.0
        for name in order:
            layer = g.layers[name]
            inputs_off = self._inputs_offchip(name, members)
            outputs_off = self._outputs_offchip(name, members)
            lc = map_layer(layer, self.acc, self.em,
                           inputs_offchip=inputs_off,
                           outputs_offchip=outputs_off,
                           weight_stream_passes=weight_passes if multi else 1)
            total += lc
            compute_cycles += lc.compute_cycles
            dram_cycles += lc.dram_cycles
        return (total.energy_pj, max(compute_cycles, dram_cycles),
                total.dram_read_words, total.dram_write_words,
                total.act_write_events, total.macs)

    def _inputs_offchip(self, name: str, members: FrozenSet[str]) -> bool:
        preds = self.graph.preds(name)
        if not preds:
            return True                                  # graph input from DRAM
        return any(p not in members for p in preds)

    def _outputs_offchip(self, name: str, members: FrozenSet[str]) -> bool:
        succ = self.graph.succs(name)
        if not succ:
            return True                                  # model output
        return any(v not in members for v in succ)
