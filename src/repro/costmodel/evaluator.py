"""Schedule evaluation: layerwise baseline vs fused states (paper Alg. 1 l.5-9).

A :class:`FusionState` is costed group-by-group.  Because a tensor's DRAM
residency is fully determined by its producer's group membership (it goes
off-chip iff some consumer is outside the group), each group's cost depends
*only* on its member set — so group costs are memoized across the entire GA
run, which is what makes the paper's P=100 x G=500 search fast.

Group costing (multi-member groups):
  1. largest output-tile height ``t`` whose line-buffer footprint fits the
     activation buffer (``repro.core.receptive``); no feasible ``t`` =>
     the state is invalid (paper: "Any mapping where intermediate storage
     exceeds capacity is discarded as invalid").
  2. if aggregate group weights exceed the weight buffer, weights re-stream
     from DRAM once per tile pass (paper §IV).
  3. member layers are costed with intra-group edges kept on-chip; compute
     and DRAM time overlap within the group.

Hot-path notes (batched engine): for bitmask genomes the group cache is
keyed by the group's **member node-bitmask** (a Python int — one machine-word
hash instead of a frozenset of strings), member topological order comes from
integer adjacency, and :meth:`Evaluator.fitness_batch` dedupes an entire
offspring generation against the cache before costing only novel groups.
Batches are scored by the array-native
:class:`repro.core.population.PopulationEvaluator` (one ``(P, n_edges)``
matrix per generation; see that module's docstring); the per-state
:meth:`Evaluator._fitness_fast` path remains as the small-batch/no-numpy
fallback and the bit-identity reference — both sum ``base + corrections`` in
ascending group-min-member order, so they agree bit-for-bit (pinned by
``tests/test_population_engine.py``).  Reference states
(``repro.core.fusion_ref``) take the original frozenset-keyed path; both
paths run the same float operations in the same order, so costs agree
bit-for-bit (pinned by ``tests/test_fusion_equivalence.py``).

Cost-backend note: the evaluator owns *memoization and fitness*, not the
numbers — those come from a pluggable :class:`repro.costmodel.base.CostModel`
(default: :class:`repro.costmodel.default.DefaultCostModel`, the paper's
mini-Timeloop mapper; alternatives register via
``repro.search.register_costmodel``).  The group caches store the scalar
``CostBreakdown.totals()`` tuples, so swapping the backend never touches the
batching machinery.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core.fusion import FusionState, iter_bits
from repro.core.graph import LayerGraph
from repro.costmodel.accelerator import Accelerator
from repro.costmodel.base import (CostBreakdown, CostModel, GroupKey,
                                  GroupTotals)
from repro.costmodel.default import DefaultCostModel
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel
from repro.obs import clock

try:                                     # numpy-backed population engine
    from repro.core.population import (MIN_BATCH, PopulationEvaluator,
                                       engine_mode)
    _HAVE_POP = True
except ImportError:                      # pragma: no cover - no numpy
    _HAVE_POP = False
    MIN_BATCH = 1 << 62

_MISSING = object()

#: objectives the evaluator scores natively (ScheduleCost.metric and the
#: batched fitness hot path); repro.search registers exactly these as
#: built-ins and routes anything else through the generic evaluate() path
NATIVE_OBJECTIVES = ("edp", "energy", "cycles", "dram")


@dataclass(frozen=True)
class ScheduleCost:
    energy_pj: float
    cycles: float
    dram_read_words: int
    dram_write_words: int
    act_write_events: int
    macs: int
    n_groups: int
    clock_hz: float = 200e6      # threaded from Accelerator.clock_mhz

    @property
    def seconds(self) -> float:
        return self.cycles / self.clock_hz

    @property
    def edp(self) -> float:
        return self.energy_pj * self.cycles

    @property
    def energy_j(self) -> float:
        return self.energy_pj * 1e-12

    def metric(self, objective: str) -> float:
        try:
            return {"edp": self.edp, "energy": self.energy_pj,
                    "cycles": self.cycles,
                    "dram": float(self.dram_read_words
                                  + self.dram_write_words),
                    }[objective]
        except KeyError:
            raise ValueError(
                f"unknown objective {objective!r}; ScheduleCost scores "
                f"{', '.join(NATIVE_OBJECTIVES)} natively — register other "
                f"metrics via repro.search.register_objective") from None

    @classmethod
    def from_groups(cls, groups: Sequence["GroupCost"], clock_hz: float
                    ) -> "ScheduleCost":
        """Declarative assembly from per-group totals tuples
        (``CostBreakdown.totals()``), summed in schedule order."""
        e = 0.0
        c = 0.0
        dr = dw = aw = mc = 0
        for g in groups:
            e += g[0]
            c += g[1]
            dr += g[2]
            dw += g[3]
            aw += g[4]
            mc += g[5]
        return cls(
            energy_pj=e, cycles=c, dram_read_words=dr, dram_write_words=dw,
            act_write_events=aw, macs=mc, n_groups=len(groups),
            clock_hz=clock_hz)


# group cost record: (energy_pj, cycles, dram_read, dram_write,
#                     act_write_events, macs) — or None if over-capacity
# (the cached form of CostBreakdown.totals(); GroupKey/GroupTotals live in
# repro.costmodel.base and are re-exported here for compatibility)
GroupCost = GroupTotals


#: what Evaluator accepts as its cost backend: a live CostModel, a factory
#: ``(graph, acc, em) -> CostModel`` (e.g. the class itself), or None for
#: the default model
CostModelLike = Union[CostModel, Callable[..., CostModel], None]


class Evaluator:
    """Memoizing schedule evaluator for one (graph, accelerator, costmodel)
    triple."""

    def __init__(self, graph: LayerGraph, acc: Accelerator,
                 em: EnergyModel = DEFAULT_ENERGY,
                 costmodel: CostModelLike = None):
        self.graph = graph
        self.acc = acc
        self.em = em
        self.cg = graph.compiled()
        if costmodel is None:
            self.costmodel: CostModel = DefaultCostModel(graph, acc, em)
        elif isinstance(costmodel, CostModel):
            self.costmodel = costmodel
        else:
            self.costmodel = costmodel(graph, acc, em)
        self.clock_hz = self.costmodel.clock_hz
        self._group_cache: Dict[GroupKey, GroupCost] = {}
        # multi-member group mask -> cost delta vs its members' singleton
        # costs (the fast fitness path sums base + these corrections)
        self._corr: Dict[int, GroupCost] = {}
        # layerwise scalar sums + per-objective baseline metrics (lazy)
        self._base: Optional[tuple] = None
        self.evals = 0
        self.group_hits = 0          # group-cost lookups served from cache
        self.group_misses = 0        # novel groups actually costed
        self.batch_states = 0        # states seen by fitness_batch
        self.batch_unique = 0        # ... of which had a novel genome
        self._layerwise: Optional[ScheduleCost] = None
        self._pop: Optional["PopulationEvaluator"] = None
        self._pop_mode = engine_mode() if _HAVE_POP else "off"
        # telemetry collector (repro.obs) or None; checked once per *batch*
        # and once per group-cache miss — never per offspring — so the
        # disabled path costs one attribute load
        self._obs = None

    def attach_telemetry(self, collector) -> None:
        """Attach a :class:`repro.obs.TelemetryCollector` (None detaches).
        Purely observational: fitness values, cache contents, and counter
        semantics are unchanged whether or not one is attached."""
        self._obs = collector
        if collector is not None:
            collector.bind_evaluator(self)

    # ---- public API ----------------------------------------------------------------
    def layerwise(self) -> ScheduleCost:
        if self._layerwise is None:
            self._layerwise = self.evaluate(FusionState.layerwise(self.graph))
            assert self._layerwise is not None
        return self._layerwise

    def evaluate(self, state) -> Optional[ScheduleCost]:
        """Total cost, or None if the state is invalid (unschedulable or
        over-capacity).  Accepts bitmask states (fast path) and reference
        states (frozenset path)."""
        self.evals += 1
        if not state.is_schedulable():
            return None
        if hasattr(state, "group_masks"):
            return self._evaluate_keys(state.group_masks())
        return self._evaluate_keys(state.groups())

    def fitness(self, state, objective: str = "edp") -> float:
        """Paper Alg. 1 line 9: F = Eval_layerwise / Eval_new (0 if invalid)."""
        cost = self.evaluate(state)
        if cost is None:
            return 0.0
        new = cost.metric(objective)
        return self.layerwise().metric(objective) / new if new > 0 else 0.0

    def fitness_batch(self, states: Sequence[FusionState],
                      objective: str = "edp") -> List[float]:
        """Fitness for a whole offspring generation (GA hot path).

        Dedupes the generation by genome against the mask-keyed caches, then
        scores the novel genomes through the array-native population engine
        (:meth:`population`) — one ``(P, n_edges)`` matrix per call — falling
        back to the per-state :meth:`_fitness_fast` path for small batches,
        non-native objectives, or ``REPRO_POP_ENGINE=off``.  Both paths sum
        ``base + corrections`` in ascending group-min-member order, so their
        results are bit-for-bit identical; values may differ from
        :meth:`fitness` by float re-association only (~1 ulp), and ``run_ga``
        re-scores its final winner exactly.
        """
        self.batch_states += len(states)
        keys = [s.key() for s in states]
        uniq: Dict[int, float] = {}
        todo: List[FusionState] = []
        for s, k in zip(states, keys):
            if k not in uniq:
                uniq[k] = 0.0
                todo.append(s)
        self.batch_unique += len(uniq)
        obs = self._obs
        if obs is not None:
            t0w, t0p = clock.now(), clock.perf_counter()
            m0 = self.group_misses
        if (self._pop_mode != "off" and len(todo) >= MIN_BATCH
                and objective in NATIVE_OBJECTIVES
                and todo[0].cg is self.cg):
            fits = self.population().fitness_masks(
                [s.mask for s in todo], objective)
            for s, f in zip(todo, fits.tolist()):
                uniq[s.mask] = f
            engine = self._pop.backend
        else:
            for s in todo:
                uniq[s.key()] = self._fitness_fast(s, objective)
            engine = "scalar"
        out = [uniq[k] for k in keys]
        if obs is not None:
            obs.record_batch(len(states), len(todo), out, engine, t0w,
                             clock.perf_counter() - t0p,
                             self.group_misses - m0)
        return out

    def fitness_batch_unique(self, states: Sequence[FusionState],
                             objective: str = "edp") -> List[float]:
        """:meth:`fitness_batch` for callers that already deduped ``states``
        by genome (the GA loop's run-level cache does) — skips the per-state
        re-keying pass and returns fitness in input order.  Same engine
        routing, bit-identical results."""
        self.batch_states += len(states)
        self.batch_unique += len(states)
        obs = self._obs
        if obs is not None:
            t0w, t0p = clock.now(), clock.perf_counter()
            m0 = self.group_misses
        if (self._pop_mode != "off" and len(states) >= MIN_BATCH
                and objective in NATIVE_OBJECTIVES
                and states[0].cg is self.cg):
            out = self.population().fitness_masks(
                [s.mask for s in states], objective).tolist()
            engine = self._pop.backend
        else:
            out = [self._fitness_fast(s, objective) for s in states]
            engine = "scalar"
        if obs is not None:
            obs.record_batch(len(states), len(states), out, engine, t0w,
                             clock.perf_counter() - t0p,
                             self.group_misses - m0)
        return out

    def population(self, backend: Optional[str] = None
                   ) -> "PopulationEvaluator":
        """The batched population engine bound to this evaluator (lazy;
        shares the group-correction caches).  Building it up front — e.g.
        before forking island workers — lets every worker inherit the static
        graph tables and the layerwise baseline via copy-on-write."""
        if not _HAVE_POP:
            raise RuntimeError("population engine requires numpy")
        if self._pop is None:
            self._ensure_base()
            self._pop = PopulationEvaluator(self, backend)
        return self._pop

    def _ensure_base(self) -> tuple:
        """Layerwise scalar sums + per-objective baseline metrics (lazy)."""
        if self._base is None:
            lw = self.layerwise()
            self._base = (lw.energy_pj, lw.cycles, lw.dram_read_words,
                          lw.dram_write_words, lw.act_write_events, lw.macs,
                          {obj: lw.metric(obj) for obj in NATIVE_OBJECTIVES})
        return self._base

    def _fitness_fast(self, state: FusionState, objective: str) -> float:
        """Baseline-plus-corrections fitness for bitmask states — the
        canonical scalar path: corrections are applied in ascending order of
        each group's minimum member, which is exactly the summation order the
        batched engine reproduces (``tests/test_population_engine.py`` pins
        the bit-identity)."""
        sched = state._sched                 # inlined is_schedulable (hot path)
        if sched is None:
            sched = state.is_schedulable()
        if not sched:
            return 0.0
        base = self._ensure_base()
        corr = self._corr
        corr_get = corr.get
        hits = 0
        e, c, dr, dw, aw, mc = base[:6]
        mgroups = state._mgroups             # inlined multi_masks (hot path)
        if mgroups is None:
            mgroups = state.multi_masks()
        # canonical order: ascending minimum member (= lowest set bit)
        for gm in sorted(mgroups, key=lambda m: m & -m):
            d = corr_get(gm, _MISSING)
            if d is _MISSING:
                d = self._compute_correction(gm)
                corr[gm] = d
            else:
                hits += 1
            if d is None:
                self.group_hits += hits
                return 0.0                   # over-capacity group: invalid
            e += d[0]
            c += d[1]
            dr += d[2]
            dw += d[3]
            aw += d[4]
            mc += d[5]
        self.group_hits += hits
        if objective == "edp":
            new = e * c
        elif objective == "energy":
            new = e
        elif objective == "cycles":
            new = c
        else:
            new = float(dr + dw)
        return base[6][objective] / new if new > 0 else 0.0

    def _compute_correction(self, gmask: int) -> GroupCost:
        """Cost delta of fusing ``gmask``'s members vs leaving each layerwise."""
        g = self._group_cost(gmask)
        if g is None:
            return None
        e, c, dr, dw, aw, mc = g
        for i in iter_bits(gmask):
            s = self._group_cost(1 << i)
            e -= s[0]
            c -= s[1]
            dr -= s[2]
            dw -= s[3]
            aw -= s[4]
            mc -= s[5]
        return (e, c, dr, dw, aw, mc)

    def _group_cost(self, key: GroupKey) -> GroupCost:
        cached = self._group_cache.get(key, _MISSING)
        if cached is _MISSING:
            obs = self._obs
            if obs is None:
                bd = self.costmodel.cost_group(key)
            else:                    # time novel-group costing (miss path
                t0 = clock.perf_counter()   # only: hits never pay this)
                bd = self.costmodel.cost_group(key)
                obs.note_group_costed(clock.perf_counter() - t0)
            cached = None if bd is None else bd.totals()
            self._group_cache[key] = cached
            self.group_misses += 1
        else:
            self.group_hits += 1
        return cached

    def breakdowns(self, state) -> Optional[List[CostBreakdown]]:
        """Per-group :class:`CostBreakdown` for ``state``'s groups (in
        group order), or None if the state is unschedulable / any group is
        infeasible.  Recomputed through the cost model — this is the
        reporting path (artifacts, ``repro report``), not the GA hot path.
        """
        if not state.is_schedulable():
            return None
        keys = state.group_masks() if hasattr(state, "group_masks") \
            else state.groups()
        out = self.costmodel.batch(keys)
        return None if any(bd is None for bd in out) else out

    def cache_stats(self) -> Dict[str, float]:
        """Cache-effectiveness counters.  ``group_hit_rate`` covers explicit
        group-cost lookups only; ``batch_evals_per_sec`` is the headline
        throughput of the array-native population engine (states scored per
        second of in-engine time; 0.0 when every batch took the scalar
        fallback)."""
        touches = self.group_hits + self.group_misses
        stats = {
            "unique_groups": len(self._group_cache),
            "group_hits": self.group_hits,
            "group_misses": self.group_misses,
            "group_hit_rate": self.group_hits / touches if touches else 0.0,
            "states_evaluated": self.evals,
            "batch_states": self.batch_states,
            "batch_unique": self.batch_unique,
            "pop_backend": "off",
            "pop_batches": 0,
            "batch_time_s": 0.0,
            "batch_evals_per_sec": 0.0,
        }
        if self._pop is not None:
            ps = self._pop.stats()
            stats.update(
                pop_backend=ps["backend"], pop_batches=ps["batches"],
                batch_time_s=ps["batch_time_s"],
                batch_evals_per_sec=ps["batch_evals_per_sec"])
        return stats

    # ---- internals ------------------------------------------------------------------
    def _evaluate_keys(self, keys: Sequence[GroupKey]
                       ) -> Optional[ScheduleCost]:
        totals = []
        for key in keys:
            g = self._group_cost(key)
            if g is None:
                return None
            totals.append(g)
        return ScheduleCost.from_groups(totals, self.clock_hz)
