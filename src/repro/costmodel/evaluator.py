"""Schedule evaluation: layerwise baseline vs fused states (paper Alg. 1 l.5-9).

A :class:`FusionState` is costed group-by-group.  Because a tensor's DRAM
residency is fully determined by its producer's group membership (it goes
off-chip iff some consumer is outside the group), each group's cost depends
*only* on its member set — so group costs are memoized across the entire GA
run, which is what makes the paper's P=100 x G=500 search fast.

Group costing (multi-member groups):
  1. largest output-tile height ``t`` whose line-buffer footprint fits the
     activation buffer (``repro.core.receptive``); no feasible ``t`` =>
     the state is invalid (paper: "Any mapping where intermediate storage
     exceeds capacity is discarded as invalid").
  2. if aggregate group weights exceed the weight buffer, weights re-stream
     from DRAM once per tile pass (paper §IV).
  3. member layers are costed with intra-group edges kept on-chip; compute
     and DRAM time overlap within the group.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.fusion import FusionState
from repro.core.graph import LayerGraph
from repro.core.receptive import max_tile_rows
from repro.core.toposort import topological_sort_edges
from repro.costmodel.accelerator import Accelerator
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel
from repro.costmodel.mapper import LayerCost, map_layer


@dataclass(frozen=True)
class ScheduleCost:
    energy_pj: float
    cycles: float
    dram_read_words: int
    dram_write_words: int
    act_write_events: int
    macs: int
    n_groups: int

    @property
    def seconds(self) -> float:
        return self.cycles / 200e6          # evaluated clock is set per-arch

    @property
    def edp(self) -> float:
        return self.energy_pj * self.cycles

    @property
    def energy_j(self) -> float:
        return self.energy_pj * 1e-12

    def metric(self, objective: str) -> float:
        return {"edp": self.edp, "energy": self.energy_pj,
                "cycles": self.cycles,
                "dram": float(self.dram_read_words + self.dram_write_words),
                }[objective]


class Evaluator:
    """Memoizing schedule evaluator for one (graph, accelerator) pair."""

    def __init__(self, graph: LayerGraph, acc: Accelerator,
                 em: EnergyModel = DEFAULT_ENERGY):
        self.graph = graph
        self.acc = acc
        self.em = em
        self._group_cache: Dict[FrozenSet[str], Optional[Tuple[LayerCost, float]]] = {}
        self.evals = 0
        self._layerwise: Optional[ScheduleCost] = None

    # ---- public API ----------------------------------------------------------------
    def layerwise(self) -> ScheduleCost:
        if self._layerwise is None:
            self._layerwise = self.evaluate(FusionState.layerwise(self.graph))
            assert self._layerwise is not None
        return self._layerwise

    def evaluate(self, state: FusionState) -> Optional[ScheduleCost]:
        """Total cost, or None if the state is invalid (unschedulable or
        over-capacity)."""
        self.evals += 1
        if not state.is_schedulable():
            return None
        total = LayerCost()
        cycles = 0.0
        groups = state.groups()
        for g in groups:
            cached = self._group_cost(g)
            if cached is None:
                return None
            gcost, gcycles = cached
            total += gcost
            cycles += gcycles
        return ScheduleCost(
            energy_pj=total.energy_pj, cycles=cycles,
            dram_read_words=total.dram_read_words,
            dram_write_words=total.dram_write_words,
            act_write_events=total.act_write_events,
            macs=total.macs, n_groups=len(groups))

    def fitness(self, state: FusionState, objective: str = "edp") -> float:
        """Paper Alg. 1 line 9: F = Eval_layerwise / Eval_new (0 if invalid)."""
        cost = self.evaluate(state)
        if cost is None:
            return 0.0
        new = cost.metric(objective)
        return self.layerwise().metric(objective) / new if new > 0 else 0.0

    # ---- internals ------------------------------------------------------------------
    def _group_cost(self, members: FrozenSet[str]
                    ) -> Optional[Tuple[LayerCost, float]]:
        if members in self._group_cache:
            return self._group_cache[members]
        cost = self._compute_group_cost(members)
        self._group_cache[members] = cost
        return cost

    def _compute_group_cost(self, members: FrozenSet[str]
                            ) -> Optional[Tuple[LayerCost, float]]:
        g = self.graph
        order = topological_sort_edges(
            [n for n in g.names if n in members], g.edges)
        multi = len([n for n in order if g.layers[n].macs]) > 1

        weight_passes = 1
        if multi and len(order) > 1:
            t = max_tile_rows(g, order, self.acc.act_buf_words)
            if t == 0:
                return None                              # over-capacity: invalid
            group_w = sum(g.layers[n].weight_size for n in order)
            if group_w > self.acc.weight_buf_words:
                sink_p = max((g.layers[n].p or 1) for n in order)
                weight_passes = math.ceil(sink_p / t)

        total = LayerCost()
        compute_cycles = 0.0
        dram_cycles = 0.0
        for name in order:
            layer = g.layers[name]
            inputs_off = self._inputs_offchip(name, members)
            outputs_off = self._outputs_offchip(name, members)
            lc = map_layer(layer, self.acc, self.em,
                           inputs_offchip=inputs_off,
                           outputs_offchip=outputs_off,
                           weight_stream_passes=weight_passes if multi else 1)
            total += lc
            compute_cycles += lc.compute_cycles
            dram_cycles += lc.dram_cycles
        # compute/DRAM overlap across the whole group pipeline
        group_cycles = max(compute_cycles, dram_cycles)
        return total, group_cycles

    def _inputs_offchip(self, name: str, members: FrozenSet[str]) -> bool:
        preds = self.graph.preds(name)
        if not preds:
            return True                                  # graph input from DRAM
        return any(p not in members for p in preds)

    def _outputs_offchip(self, name: str, members: FrozenSet[str]) -> bool:
        succ = self.graph.succs(name)
        if not succ:
            return True                                  # model output
        return any(v not in members for v in succ)
