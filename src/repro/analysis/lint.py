"""AST determinism lint for the engine packages (``repro lint``).

Everything this repo pins — bit-for-bit engine equivalence, fixed-seed
search trajectories, ``ir1:`` fingerprints, content-addressed store keys
— rests on determinism invariants that, until now, nothing enforced
mechanically.  This linter walks the ASTs of the engine packages
(``src/repro/{core,search,serve,costmodel,ir,hw}`` by default) and flags
the four ways nondeterminism historically sneaks into systems like this:

``global-random``
    Module-global RNG state (``random.random()``, ``np.random.shuffle``,
    ``from random import randint``): unseeded and shared across callers.
    Constructing *owned* generators (``random.Random(seed)``,
    ``np.random.default_rng(seed)``) is the sanctioned pattern and is not
    flagged.
``wall-clock``
    Wall-time and entropy reads (``time.time``/``time_ns``,
    ``datetime.now``/``utcnow``/``today``, ``os.urandom``,
    ``uuid.uuid1``/``uuid4``) in engine paths.  Monotonic timers
    (``perf_counter``/``monotonic``/``process_time``) are fine — they
    measure, they don't feed results.
``unordered-iter``
    Direct iteration over ``set`` literals, ``set()``/``frozenset()``
    calls, or ``os.listdir()`` in ``for``/comprehensions.  String hashing
    is salted per process and directory order is filesystem-dependent, so
    anything derived from such an iteration (fingerprints, store keys,
    RNG consumption order) varies across runs unless ``sorted()`` wraps
    the iterable.
``mutable-default``
    Mutable default arguments (``def f(x, cache={})``): call-order-
    dependent shared state.
``import-boundary``
    Architectural isolation pins, declared as a ``pyproject.toml`` table
    mapping a file to the modules it must never import (directly, lazy
    imports included)::

        [tool.repro.lint.boundaries]
        "src/repro/analysis/verify.py" = [
            "repro.core.fusion", "repro.costmodel.evaluator"]

    The independent checkers (``analysis.verify``, ``analysis.spacemap``)
    must share no code with the engine they check — an engine bug must
    not be able to hide its own evidence.  Boundary files are checked on
    *every* lint run, whatever paths were passed; a table row naming a
    missing file is itself a finding, so the table cannot rot.
``clock-seam``
    Instrumented modules must take *every* clock reading — wall or
    monotonic — through :mod:`repro.obs.clock`, the engine's single
    audited time seam, declared as a ``pyproject.toml`` path list::

        [tool.repro.lint.clock_seam]
        paths = ["src/repro/search/session.py", ...]

    Any direct ``time.*`` / ``datetime.*`` call (or ``from time import
    ...``) in a listed file is a finding — stricter than ``wall-clock``,
    which permits monotonic timers: telemetry timestamps that bypass the
    seam fragment the determinism audit across call sites.  Like the
    boundary table, listed files are checked on every run and a row
    naming a missing file is itself a finding.

Findings are suppressed only through the allowlist in ``pyproject.toml``:

.. code-block:: toml

    [tool.repro.lint]
    allow = [
        "src/repro/search/artifact.py::wall-clock::time.time::reason...",
    ]

Each entry is ``path::rule::symbol::justification`` — four ``::``-joined
fields, justification mandatory.  Malformed entries are themselves
findings (``bad-allow``), and entries that no longer match any finding
are findings too (``stale-allow``), so the allowlist can neither rot nor
hide unexplained suppressions.  The TOML fragment is read with a
purpose-built mini-parser because the floor Python here (3.10) ships
neither ``tomllib`` nor a bundled ``tomli``.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

#: packages linted by default (relative to ``<root>/src/repro``)
DEFAULT_PACKAGES = ("core", "search", "serve", "costmodel", "ir", "hw",
                    "obs")

RULES = ("global-random", "wall-clock", "unordered-iter", "mutable-default",
         "import-boundary", "clock-seam")

#: RNG *constructors*: owning a seeded generator is the sanctioned pattern
_RNG_CONSTRUCTORS = {"Random", "SystemRandom", "default_rng", "Generator",
                     "RandomState", "SeedSequence", "PCG64", "Philox",
                     "MT19937", "BitGenerator"}
_WALL_TIME = {"time", "time_ns"}
_WALL_DATETIME = {"now", "utcnow", "today"}
_WALL_UUID = {"uuid1", "uuid4"}


@dataclass(frozen=True)
class Finding:
    """One lint hit.  ``symbol`` is the stable handle allowlist entries
    match on (e.g. ``time.time``, ``os.listdir``, a function name for
    ``mutable-default``)."""

    path: str
    line: int
    rule: str
    symbol: str
    message: str

    def describe(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "symbol": self.symbol, "message": self.message}


@dataclass(frozen=True)
class AllowEntry:
    path: str
    rule: str
    symbol: str
    justification: str
    raw: str

    def matches(self, f: Finding) -> bool:
        return (self.path == f.path and self.rule == f.rule
                and self.symbol == f.symbol)


def parse_allow_entries(raw: Sequence[str]
                        ) -> Tuple[List[AllowEntry], List[Finding]]:
    """Parse raw ``path::rule::symbol::justification`` strings; malformed
    entries (wrong arity, empty field, unknown rule) become ``bad-allow``
    findings instead of silently suppressing nothing."""
    entries: List[AllowEntry] = []
    bad: List[Finding] = []
    for s in raw:
        parts = s.split("::")
        if len(parts) != 4 or not all(p.strip() for p in parts):
            bad.append(Finding(
                "pyproject.toml", 0, "bad-allow", s,
                f"allowlist entry {s!r} is not "
                f"'path::rule::symbol::justification' with every field "
                f"(including the justification) non-empty"))
            continue
        path, rule, symbol, just = (p.strip() for p in parts)
        if rule not in RULES:
            bad.append(Finding(
                "pyproject.toml", 0, "bad-allow", s,
                f"allowlist entry {s!r} names unknown rule {rule!r} "
                f"(rules: {', '.join(RULES)})"))
            continue
        entries.append(AllowEntry(path, rule, symbol, just, s))
    return entries, bad


def load_pyproject_allow(pyproject_path: str) -> List[str]:
    """The raw ``[tool.repro.lint] allow`` list, via a mini TOML reader
    (section + one string array; the floor interpreter has no tomllib)."""
    try:
        with open(pyproject_path) as f:
            text = f.read()
    except FileNotFoundError:
        return []
    sec = re.search(r"(?ms)^\[tool\.repro\.lint\]\s*$(.*?)(?=^\[|\Z)", text)
    if not sec:
        return []
    arr = re.search(r"(?ms)^allow\s*=\s*\[(.*?)\]", sec.group(1))
    if not arr:
        return []
    return [m.group(1) for m in
            re.finditer(r'"((?:[^"\\]|\\.)*)"', arr.group(1))]


def load_pyproject_boundaries(pyproject_path: str) -> Dict[str, List[str]]:
    """The ``[tool.repro.lint.boundaries]`` table — quoted file path ->
    list of module names it must not import — read with the same mini
    TOML reader as the allowlist."""
    try:
        with open(pyproject_path) as f:
            text = f.read()
    except FileNotFoundError:
        return {}
    sec = re.search(
        r"(?ms)^\[tool\.repro\.lint\.boundaries\]\s*$(.*?)(?=^\[|\Z)", text)
    if not sec:
        return {}
    out: Dict[str, List[str]] = {}
    for row in re.finditer(
            r'(?ms)^"((?:[^"\\]|\\.)*)"\s*=\s*\[(.*?)\]', sec.group(1)):
        out[row.group(1)] = [m.group(1) for m in
                             re.finditer(r'"((?:[^"\\]|\\.)*)"',
                                         row.group(2))]
    return out


def load_pyproject_clock_seam(pyproject_path: str) -> List[str]:
    """The ``[tool.repro.lint.clock_seam] paths`` list — files whose every
    clock reading must route through ``repro.obs.clock`` — read with the
    same mini TOML reader as the allowlist."""
    try:
        with open(pyproject_path) as f:
            text = f.read()
    except FileNotFoundError:
        return []
    sec = re.search(
        r"(?ms)^\[tool\.repro\.lint\.clock_seam\]\s*$(.*?)(?=^\[|\Z)", text)
    if not sec:
        return []
    arr = re.search(r"(?ms)^paths\s*=\s*\[(.*?)\]", sec.group(1))
    if not arr:
        return []
    return [m.group(1) for m in
            re.finditer(r'"((?:[^"\\]|\\.)*)"', arr.group(1))]


def check_clock_seam(root: str, seam_paths: Sequence[str]) -> List[Finding]:
    """Enforce the clock-seam table: in a listed file, every ``time.*`` /
    ``datetime.*`` call — monotonic timers included — and every ``from
    time import ...`` binding is a finding; time flows only through
    :mod:`repro.obs.clock`.  Like the boundary table, a row naming a
    missing file is itself a finding."""
    findings: List[Finding] = []
    for rel in sorted(seam_paths):
        full = os.path.join(root, rel)
        shown = rel.replace(os.sep, "/")
        if not os.path.isfile(full):
            findings.append(Finding(
                "pyproject.toml", 0, "clock-seam", rel,
                f"clock_seam table names {rel!r} but no such file exists "
                f"under the root — fix the path or delete the row"))
            continue
        with open(full) as f:
            source = f.read()
        try:
            tree = ast.parse(source, filename=full)
        except SyntaxError as e:
            findings.append(Finding(
                shown, e.lineno or 0, "parse-error", "syntax",
                f"file does not parse: {e.msg}"))
            continue
        # pass 1: names this file binds to the time/datetime modules (or
        # the datetime/date classes); `from time import X` is flagged at
        # the import itself — the binding bypasses the seam however it is
        # later called
        time_mods: Set[str] = set()
        dt_mods: Set[str] = set()
        dt_classes: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".", 1)[0]
                    if alias.name == "time":
                        time_mods.add(bound)
                    elif alias.name == "datetime":
                        dt_mods.add(bound)
            elif isinstance(node, ast.ImportFrom) and not node.level:
                if node.module == "time":
                    for alias in node.names:
                        findings.append(Finding(
                            shown, node.lineno, "clock-seam",
                            f"time.{alias.name}",
                            f"'from time import {alias.name}' bypasses "
                            f"the repro.obs.clock seam — call "
                            f"clock.now()/clock.perf_counter()/"
                            f"clock.unix_time() instead"))
                elif node.module == "datetime":
                    for alias in node.names:
                        if alias.name in ("datetime", "date"):
                            dt_classes.add(alias.asname or alias.name)
        # pass 2: every call through those bindings is a seam bypass
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            parts = _dotted(node.func)
            if not parts:
                continue
            head, last = parts[0], parts[-1]
            if head in time_mods and len(parts) == 2:
                findings.append(Finding(
                    shown, node.lineno, "clock-seam", f"time.{last}",
                    f"{'.'.join(parts)}() bypasses the repro.obs.clock "
                    f"seam (monotonic timers included — telemetry "
                    f"timestamps must share one audited source)"))
            elif (head in dt_classes and len(parts) == 2) or \
                    (head in dt_mods and len(parts) == 3
                     and parts[1] in ("datetime", "date")):
                findings.append(Finding(
                    shown, node.lineno, "clock-seam", f"datetime.{last}",
                    f"{'.'.join(parts)}() bypasses the repro.obs.clock "
                    f"seam — route wall-time reads through clock.*"))
    return findings


def check_boundaries(root: str, boundaries: Dict[str, Sequence[str]]
                     ) -> List[Finding]:
    """Enforce the import-boundary table: every ``Import``/``ImportFrom``
    in a listed file (top-level or lazy) is matched against that file's
    forbidden module prefixes.  ``from repro.core import fusion`` counts
    as importing ``repro.core.fusion``; relative imports are out of scope
    (the pinned modules live in other packages)."""
    findings: List[Finding] = []
    for rel in sorted(boundaries):
        full = os.path.join(root, rel)
        shown = rel.replace(os.sep, "/")
        forbidden = tuple(boundaries[rel])
        if not os.path.isfile(full):
            findings.append(Finding(
                "pyproject.toml", 0, "import-boundary", rel,
                f"boundary table names {rel!r} but no such file exists "
                f"under the root — fix the path or delete the row"))
            continue
        with open(full) as f:
            source = f.read()
        try:
            tree = ast.parse(source, filename=full)
        except SyntaxError as e:
            findings.append(Finding(
                shown, e.lineno or 0, "parse-error", "syntax",
                f"file does not parse: {e.msg}"))
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and not node.level:
                mods = [node.module] + [f"{node.module}.{a.name}"
                                        for a in node.names]
            else:
                continue
            for mod in mods:
                hit = next((fb for fb in forbidden
                            if mod == fb or mod.startswith(fb + ".")), None)
                if hit is not None:
                    findings.append(Finding(
                        shown, getattr(node, "lineno", 0),
                        "import-boundary", hit,
                        f"imports {mod}, but the boundary table pins this "
                        f"file against {hit}: the independent checker "
                        f"must share no code with the engine it checks"))
                    break                    # one finding per import stmt
    return findings


def _dotted(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` attribute chain as ``["a", "b", "c"]`` (None when the
    base is an expression, e.g. ``get_rng().random``)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        # local names bound to each watched module / class
        self.random_mods: Set[str] = set()     # `random`
        self.numpy_mods: Set[str] = set()      # `numpy`
        self.np_random_mods: Set[str] = set()  # `numpy.random` aliases
        self.time_mods: Set[str] = set()
        self.os_mods: Set[str] = set()
        self.uuid_mods: Set[str] = set()
        self.datetime_mods: Set[str] = set()   # the `datetime` module
        self.datetime_classes: Set[str] = set()  # `datetime`/`date` classes

    def _hit(self, node: ast.AST, rule: str, symbol: str,
             message: str) -> None:
        self.findings.append(Finding(
            self.path, getattr(node, "lineno", 0), rule, symbol, message))

    # ---- imports ----------------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            bound = alias.asname or alias.name.split(".", 1)[0]
            if alias.name == "random":
                self.random_mods.add(bound)
            elif alias.name == "numpy":
                self.numpy_mods.add(bound)
            elif alias.name == "numpy.random":
                if alias.asname:
                    self.np_random_mods.add(alias.asname)
                else:
                    self.numpy_mods.add("numpy")
            elif alias.name == "time":
                self.time_mods.add(bound)
            elif alias.name == "os":
                self.os_mods.add(bound)
            elif alias.name == "uuid":
                self.uuid_mods.add(bound)
            elif alias.name == "datetime":
                self.datetime_mods.add(bound)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        for alias in node.names:
            name = alias.name
            if mod == "random" and name not in _RNG_CONSTRUCTORS:
                self._hit(node, "global-random", f"random.{name}",
                          f"'from random import {name}' binds module-"
                          f"global RNG state; own a random.Random(seed)")
            elif mod == "numpy.random" and name not in _RNG_CONSTRUCTORS:
                self._hit(node, "global-random", f"numpy.random.{name}",
                          f"'from numpy.random import {name}' binds "
                          f"global RNG state; own a default_rng(seed)")
            elif mod == "time" and name in _WALL_TIME:
                self._hit(node, "wall-clock", f"time.{name}",
                          f"'from time import {name}' pulls wall-clock "
                          f"into an engine path")
            elif mod == "os" and name == "urandom":
                self._hit(node, "wall-clock", "os.urandom",
                          "'from os import urandom' pulls entropy into "
                          "an engine path")
            elif mod == "uuid" and name in _WALL_UUID:
                self._hit(node, "wall-clock", f"uuid.{name}",
                          f"'from uuid import {name}' is time/entropy-"
                          f"derived")
            elif mod == "datetime" and name in ("datetime", "date"):
                self.datetime_classes.add(alias.asname or name)

    # ---- calls ------------------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        parts = _dotted(node.func)
        if parts:
            self._check_call(node, parts)
        self.generic_visit(node)

    def _check_call(self, node: ast.Call, parts: List[str]) -> None:
        head, last = parts[0], parts[-1]
        if head in self.random_mods and len(parts) == 2 \
                and last not in _RNG_CONSTRUCTORS:
            self._hit(node, "global-random", f"random.{last}",
                      f"{'.'.join(parts)}() uses the module-global RNG; "
                      f"thread an owned random.Random(seed) instead")
        elif ((head in self.numpy_mods and len(parts) == 3
               and parts[1] == "random")
              or (head in self.np_random_mods and len(parts) == 2)) \
                and last not in _RNG_CONSTRUCTORS:
            self._hit(node, "global-random", f"numpy.random.{last}",
                      f"{'.'.join(parts)}() uses numpy's global RNG; "
                      f"thread an owned np.random.default_rng(seed)")
        elif head in self.time_mods and len(parts) == 2 \
                and last in _WALL_TIME:
            self._hit(node, "wall-clock", f"time.{last}",
                      f"{'.'.join(parts)}() reads the wall clock in an "
                      f"engine path (perf_counter/monotonic measure "
                      f"without feeding results)")
        elif head in self.os_mods and len(parts) == 2 \
                and last == "urandom":
            self._hit(node, "wall-clock", "os.urandom",
                      f"{'.'.join(parts)}() reads OS entropy in an "
                      f"engine path")
        elif head in self.uuid_mods and len(parts) == 2 \
                and last in _WALL_UUID:
            self._hit(node, "wall-clock", f"uuid.{last}",
                      f"{'.'.join(parts)}() is time/entropy-derived")
        elif last in _WALL_DATETIME and (
                (head in self.datetime_classes and len(parts) == 2)
                or (head in self.datetime_mods and len(parts) == 3
                    and parts[1] in ("datetime", "date"))):
            self._hit(node, "wall-clock", f"datetime.{last}",
                      f"{'.'.join(parts)}() reads the wall clock in an "
                      f"engine path")

    # ---- unordered iteration ----------------------------------------------------
    def _unordered_source(self, expr: ast.AST) -> Optional[str]:
        if isinstance(expr, ast.Set):
            return "set-literal"
        if isinstance(expr, ast.Call):
            parts = _dotted(expr.func)
            if parts == ["set"] or parts == ["frozenset"]:
                return f"{parts[0]}()"
            if parts and len(parts) == 2 and parts[0] in self.os_mods \
                    and parts[1] == "listdir":
                return "os.listdir"
            if parts == ["listdir"]:
                return "os.listdir"
        return None

    def _check_iter(self, node: ast.AST, iter_expr: ast.AST) -> None:
        src = self._unordered_source(iter_expr)
        if src is not None:
            self._hit(node, "unordered-iter", src,
                      f"iteration order of {src} is not deterministic "
                      f"across processes; wrap it in sorted() before "
                      f"anything order-sensitive consumes it")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node, node.iter)
        self.generic_visit(node)

    def _visit_comp(self, node: ast.expr) -> None:
        for gen in node.generators:      # type: ignore[attr-defined]
            self._check_iter(node, gen.iter)
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    # ---- mutable defaults -------------------------------------------------------
    def _visit_func(self, node: ast.FunctionDef) -> None:
        defaults = list(node.args.defaults) \
            + [d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set))
            if isinstance(d, ast.Call):
                parts = _dotted(d.func)
                bad = parts in (["list"], ["dict"], ["set"])
            if bad:
                self._hit(d, "mutable-default", node.name,
                          f"def {node.name}(...) has a mutable default "
                          f"argument — shared, call-order-dependent "
                          f"state; default to None")
        self.generic_visit(node)

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func


def lint_file(path: str, display_path: Optional[str] = None
              ) -> List[Finding]:
    """Lint one Python source file; syntax errors are findings, not
    crashes (a file the linter cannot parse is a file it cannot vouch
    for)."""
    shown = display_path or path
    with open(path) as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(shown, e.lineno or 0, "parse-error", "syntax",
                        f"file does not parse: {e.msg}")]
    linter = _FileLinter(shown)
    linter.visit(tree)
    return linter.findings


def _default_paths(root: str) -> List[str]:
    return [os.path.join(root, "src", "repro", pkg)
            for pkg in DEFAULT_PACKAGES]


def run_lint(root: str = ".", paths: Optional[Sequence[str]] = None,
             allow_raw: Optional[Sequence[str]] = None,
             boundaries: Optional[Dict[str, Sequence[str]]] = None,
             clock_seam: Optional[Sequence[str]] = None
             ) -> List[Finding]:
    """Lint ``paths`` (default: the engine packages under ``root``),
    enforce the import-boundary and clock-seam tables (defaults: the
    ``[tool.repro.lint.boundaries]`` / ``[tool.repro.lint.clock_seam]``
    tables — checked on *every* run, whatever ``paths`` say), apply the
    allowlist (default: ``<root>/pyproject.toml``), and return surviving
    findings — including ``bad-allow``/``stale-allow`` rows for a
    defective allowlist — sorted by location."""
    pyproject = os.path.join(root, "pyproject.toml")
    if allow_raw is None:
        allow_raw = load_pyproject_allow(pyproject)
    if boundaries is None:
        boundaries = load_pyproject_boundaries(pyproject)
    if clock_seam is None:
        clock_seam = load_pyproject_clock_seam(pyproject)
    entries, findings = parse_allow_entries(allow_raw)

    files: List[Tuple[str, str]] = []
    for p in (paths if paths is not None else _default_paths(root)):
        if os.path.isfile(p):
            files.append((p, os.path.relpath(p, root)))
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames.sort()
            for name in sorted(filenames):
                if name.endswith(".py"):
                    full = os.path.join(dirpath, name)
                    files.append((full, os.path.relpath(full, root)))

    raw_findings: List[Finding] = []
    for full, rel in files:
        raw_findings.extend(lint_file(full, rel.replace(os.sep, "/")))
    raw_findings.extend(check_boundaries(root, boundaries))
    raw_findings.extend(check_clock_seam(root, clock_seam))

    used: Set[str] = set()
    for f in raw_findings:
        matched = [e for e in entries if e.matches(f)]
        if matched:
            used.add(matched[0].raw)
        else:
            findings.append(f)
    for e in entries:
        if e.raw not in used:
            findings.append(Finding(
                "pyproject.toml", 0, "stale-allow", e.raw,
                f"allowlist entry {e.raw!r} matches no finding — the "
                f"code it excused moved or was fixed; delete the entry"))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule,
                                           f.symbol))
