"""Communication (DRAM-traffic) lower bounds for fused schedules.

"Communication Lower Bound in Convolution Accelerators" (Chen et al.,
arXiv 1911.05662 / HPCA'20) shows off-chip traffic of a convolution is
bounded below by a red-blue-pebble (Hong-Kung) term ``2 * #MACs /
sqrt(rho * S)`` — ``rho`` the maximal in-window data reuse (R*S for
convolutions, 1 for matmuls), ``S`` the on-chip capacity in words —
combined with a *memory floor*: every operand that crosses the DRAM
boundary moves at least once.  Both terms are computable statically from
the geometry the mapper already holds, which makes them a schedule
*certificate*: for any fused grouping, the modeled DRAM traffic can be
compared against a bound no execution (and no cost model that prices
plausible executions) can beat, giving each artifact an optimality gap
(ROADMAP open item 5(a)).

Two granularities:

* :func:`group_bound` — lower bound for one fused group as the engine
  prices it: the floor counts member weights once, plus the activations
  the group's boundary forces across DRAM (inputs staged from outside,
  outputs consumed outside or by nobody); the Hong-Kung term covers the
  group's aggregate MACs at the group's best window reuse.
* :func:`graph_bound` — schedule-*independent* bound: weights once, model
  sink outputs once, Hong-Kung over the whole graph's MACs.  Any legal
  schedule's traffic is >= this, so ``traffic / graph_bound - 1`` is the
  optimality gap ``repro report`` and ``repro verify`` print.

Soundness notes (why gap >= 0 holds for the in-repo cost models): the
default mapper charges every weight word at least once (re-streams only
add passes), charges a member's full input when any producer is outside
the group, and writes a member's full output when any consumer is outside
— exactly the floor's terms; the TPU roofline's traffic *equals* the
floor per group.  The Hong-Kung term uses the machine's total on-chip
words (a capacity-generous ``S`` can only lower the bound, never break
it).  ``tests/test_analysis_verify.py`` pins gap >= 0 across the
backend/workload/accelerator zoo.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Set, Tuple

from repro.core.graph import Layer, LayerGraph


def window_reuse(layer: Layer) -> int:
    """``rho``: maximal per-word data reuse inside one sliding window.

    Convolutions (dense or depthwise) reuse each input word across the
    R x S filter window; matmuls/elementwise ops have no window reuse.
    """
    if layer.kind in ("conv", "dwconv"):
        return max(layer.r * layer.s, 1)
    return 1


def hk_words(macs: int, reuse: int, onchip_words: int) -> float:
    """The Hong-Kung red-blue-pebble term: ``2 * macs / sqrt(rho * S)``
    words of off-chip traffic (0 when there is no compute or no finite
    capacity to pebble against)."""
    if macs <= 0 or onchip_words <= 0:
        return 0.0
    return 2.0 * macs / math.sqrt(max(reuse, 1) * onchip_words)


def _costed(layer: Layer) -> bool:
    """Whether the cost models charge this node at all (graph ``input``
    placeholders are free: their tensor is charged at the consumer)."""
    return not (layer.macs == 0 and layer.kind == "input")


@dataclass(frozen=True)
class TrafficBound:
    """A DRAM-traffic lower bound: ``max(memory floor, Hong-Kung)``.

    ``floor_words`` decomposes into weights read once plus boundary
    activations moved once; ``hk_words`` is the pebbling term.
    """

    floor_words: int
    hk_words: float
    macs: int
    reuse: int
    onchip_words: int

    @property
    def words(self) -> int:
        return max(self.floor_words, math.ceil(self.hk_words))


def group_bound(graph: LayerGraph, members: Sequence[str],
                onchip_words: int) -> TrafficBound:
    """Lower bound on the DRAM traffic of executing ``members`` as one
    fused group (see module docstring for the floor's terms)."""
    mset: Set[str] = set(members)
    floor = 0
    macs = 0
    reuse = 1
    for name in members:
        layer = graph.layers[name]
        if not _costed(layer):
            continue
        floor += layer.weight_size                     # read >= once
        preds = graph.preds(name)
        if not preds or any(p not in mset for p in preds):
            floor += layer.input_size                  # staged from DRAM
        succs = graph.succs(name)
        if (not succs or any(v not in mset for v in succs)) \
                and layer.output_size:
            floor += layer.output_size                 # stored to DRAM
        macs += layer.macs
        if layer.macs:
            reuse = max(reuse, window_reuse(layer))
    return TrafficBound(floor_words=floor,
                        hk_words=hk_words(macs, reuse, onchip_words),
                        macs=macs, reuse=reuse, onchip_words=onchip_words)


def schedule_bound(graph: LayerGraph, groups: Sequence[Sequence[str]],
                   onchip_words: int
                   ) -> Tuple[List[TrafficBound], int]:
    """Per-group bounds for one concrete grouping, plus their sum — the
    lower bound on this *schedule's* DRAM traffic."""
    per_group = [group_bound(graph, g, onchip_words) for g in groups]
    return per_group, sum(b.words for b in per_group)


def graph_bound(graph: LayerGraph, onchip_words: int) -> TrafficBound:
    """Schedule-independent lower bound: whatever the grouping, weights
    are read at least once, sink outputs are written at least once, and
    the Hong-Kung term covers the total compute."""
    floor = 0
    macs = 0
    reuse = 1
    for name, layer in graph.layers.items():
        if not _costed(layer):
            continue
        floor += layer.weight_size
        if not graph.succs(name) and layer.output_size:
            floor += layer.output_size
        macs += layer.macs
        if layer.macs:
            reuse = max(reuse, window_reuse(layer))
    return TrafficBound(floor_words=floor,
                        hk_words=hk_words(macs, reuse, onchip_words),
                        macs=macs, reuse=reuse, onchip_words=onchip_words)


def onchip_words_for(costmodel: str, accelerator: str) -> Optional[int]:
    """The on-chip capacity ``S`` (words) the bound should pebble against
    for a given cost backend, or None when the backend's DRAM semantics
    are unknown to this module (no certificate is sounder than a wrong
    one).

    * ``default`` — the paper's mini-Timeloop mapper: activation +
      weight SRAM of the named machine (repartition suffixes honored);
    * ``tpu`` — the TPU roofline: the VMEM activation budget
      (:data:`repro.costmodel.tpu_fusion.VMEM_BYTES`, half budgeted to
      activations, bf16 words) — weights stream, so the floor dominates.
    """
    if costmodel == "default":
        from repro.search.registry import build_accelerator
        acc = build_accelerator(accelerator)
        return acc.act_buf_words + acc.weight_buf_words
    if costmodel == "tpu":
        from repro.costmodel.tpu_fusion import VMEM_BYTES
        return int(VMEM_BYTES / 2) // 2
    return None
