"""Static analysis over schedules and the engine itself.

Two independent passes (ROADMAP open item 5(a) + determinism hygiene):

* :mod:`repro.analysis.verify` — re-derives an artifact's groups,
  schedulability, footprints, and cost consistency from its bytes alone
  (no ``core.fusion``, no evaluator) and attaches a Chen-et-al DRAM-
  traffic lower-bound :class:`~repro.analysis.verify.Certificate`;
* :mod:`repro.analysis.bounds` — the communication lower bounds the
  certificate is built from (per-group, per-schedule, whole-graph);
* :mod:`repro.analysis.lint` — AST determinism + import-boundary lint
  over the engine packages (``repro lint``; allowlist and boundary table
  in ``pyproject.toml``);
* :mod:`repro.analysis.spacemap` — static fusion-space analysis
  (``repro analyze``; ROADMAP open item 5(b)): classifies every genome
  bit as ``forced_off`` / ``free`` / ``undecided`` and factorizes the
  space into independently-searchable regions, again sharing no code
  with the engine it prunes.
"""
from repro.analysis.bounds import (TrafficBound, graph_bound, group_bound,
                                   onchip_words_for, schedule_bound)
from repro.analysis.lint import Finding, lint_file, run_lint
from repro.analysis.spacemap import (EdgeVerdict, Region, SpaceMap,
                                     build_spacemap)
from repro.analysis.verify import (Certificate, Check, VerificationReport,
                                   verify_artifact, verify_store)

__all__ = [
    "Certificate", "Check", "EdgeVerdict", "Finding", "Region", "SpaceMap",
    "TrafficBound", "VerificationReport", "build_spacemap", "graph_bound",
    "group_bound", "lint_file", "onchip_words_for", "run_lint",
    "schedule_bound", "verify_artifact", "verify_store",
]
