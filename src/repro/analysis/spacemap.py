"""Static fusion-space analysis: freeze decided genes, factorize regions.

The GA (and every other backend) searches the full ``2^E`` edge-bitmask
space, yet many fusion edges are *statically decidable* from the graph
geometry and the machine's activation capacity alone — before any search:

``forced_off``
    No grouping containing this edge fits the activation buffer.  Proved
    with a per-edge footprint **lower bound** valid for *every* group the
    edge could belong to (see :func:`edge_footprint_lb`), evaluated with
    the verifier's own receptive-field recurrence
    (:class:`repro.analysis.verify._GraphView`), not the engine's.  A
    forced-off gene can be frozen out of the genome: any genome setting
    it scores fitness 0 under any objective.
``free``
    Fusing can never break capacity (the *maximal* possible group
    footprint in the edge's region fits the buffer) and the edge's
    boundary-tensor saving upper bound is positive — flipping the gene
    on is always capacity-legal and potentially profitable.
``undecided``
    Everything else: the search must decide.

On top of the classification the DAG factorizes into **independent
regions**: node ids are topological by construction and every edge runs
from a lower id to a higher id, so a position ``p`` with *no* fusable
edge ``(u, v)`` satisfying ``u < p <= v`` is a frontier no fused group
can span — every legal schedule spills the tensors crossing it.  Groups
are therefore confined to regions, all cross-frontier condensation edges
point rightward (no cycle can cross a cut), and the evaluator's cost is
the layerwise baseline plus per-group corrections — additive across
regions.  Hence: a genome is valid iff each region's restriction is
valid, and exhaustive search may enumerate ``2^{k_r}`` masks per region
and compose winners instead of ``2^{sum k_r}`` globally (ROADMAP open
item 5(b): VGG-16's raw 2^21 space factorizes into per-region spaces of
at most 2^3 here).

Isolation pin (same as :mod:`repro.analysis.verify`, enforced by the
``import-boundary`` lint rule and ``tests/test_spacemap.py``): this
module imports **neither** ``repro.core.fusion`` **nor**
``repro.costmodel.evaluator`` — the classifier that prunes the engine's
search space shares no code with the engine it prunes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.verify import _act_capacity, _GraphView
from repro.core.graph import LayerGraph

#: the three per-edge verdicts
CLASSES = ("forced_off", "free", "undecided")


@dataclass(frozen=True)
class EdgeVerdict:
    """One edge's static classification with its numeric evidence."""

    index: int                      # genome bit position
    producer: str
    consumer: str
    verdict: str                    # one of CLASSES
    #: sound lower bound on any containing group's t=1 footprint (words);
    #: 0 when the edge can form a non-tiled (single-MAC) pair
    footprint_lb_words: int
    #: upper bound on the DRAM words fusing this edge could save
    saving_ub_words: int

    def to_dict(self) -> Dict[str, Any]:
        return {
            "index": self.index,
            "producer": self.producer,
            "consumer": self.consumer,
            "verdict": self.verdict,
            "footprint_lb_words": self.footprint_lb_words,
            "saving_ub_words": self.saving_ub_words,
        }


@dataclass(frozen=True)
class Region:
    """A maximal node-id interval no fusable edge crosses out of."""

    index: int
    lo: int                         # first node id (inclusive)
    hi: int                         # last node id (inclusive)
    nodes: Tuple[str, ...]
    edge_indices: Tuple[int, ...]   # fusable genome bits confined here

    @property
    def size(self) -> int:
        return 1 << len(self.edge_indices)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "index": self.index,
            "lo": self.lo,
            "hi": self.hi,
            "nodes": list(self.nodes),
            "edge_indices": list(self.edge_indices),
        }


@dataclass
class SpaceMap:
    """The static search-space map for one (graph, costmodel, accelerator).

    ``frozen`` genes (the forced-off bits) are excluded from mutation /
    crossover / enumeration when a search opts in via
    ``SearchSpec(spacemap=True)``; ``regions`` partition the remaining
    genes into independently-enumerable intervals.
    """

    graph_name: str
    costmodel: str
    accelerator: str
    n_edges: int
    capacity_words: Optional[int]   # None: unknown costmodel, nothing frozen
    capacity_how: str
    verdicts: List[EdgeVerdict] = field(default_factory=list)
    regions: List[Region] = field(default_factory=list)

    # ---- derived views ---------------------------------------------------------
    @property
    def forced_off(self) -> List[EdgeVerdict]:
        return [v for v in self.verdicts if v.verdict == "forced_off"]

    @property
    def free(self) -> List[EdgeVerdict]:
        return [v for v in self.verdicts if v.verdict == "free"]

    @property
    def undecided(self) -> List[EdgeVerdict]:
        return [v for v in self.verdicts if v.verdict == "undecided"]

    @property
    def frozen_indices(self) -> Tuple[int, ...]:
        """Genome bits provably useless to search (ascending)."""
        return tuple(v.index for v in self.forced_off)

    @property
    def frozen_mask(self) -> int:
        m = 0
        for i in self.frozen_indices:
            m |= 1 << i
        return m

    @property
    def active_indices(self) -> Tuple[int, ...]:
        """Genome bits the search still decides (ascending)."""
        frozen = set(self.frozen_indices)
        return tuple(i for i in range(self.n_edges) if i not in frozen)

    @property
    def genome_length(self) -> int:
        return len(self.active_indices)

    def raw_space_size(self) -> int:
        return 1 << self.n_edges

    def masked_space_size(self) -> int:
        """Genomes left after freezing forced-off bits."""
        return 1 << self.genome_length

    def factorized_states(self) -> int:
        """States an exhaustive per-region enumeration actually scores:
        ``sum_r 2^{k_r}`` instead of ``prod_r 2^{k_r}``."""
        return sum(r.size for r in self.regions)

    def largest_region_size(self) -> int:
        return max((r.size for r in self.regions), default=1)

    # ---- serialization ---------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """The compact artifact-embeddable form ``repro verify``
        re-derives and compares (no per-edge rows: those re-derive)."""
        return {
            "n_edges": self.n_edges,
            "capacity_words": self.capacity_words,
            "forced_off": [v.index for v in self.forced_off],
            "free": [v.index for v in self.free],
            "regions": [[r.lo, r.hi] for r in self.regions],
            "genome_length": self.genome_length,
            "factorized_states": self.factorized_states(),
        }

    def to_dict(self) -> Dict[str, Any]:
        return {
            "graph": self.graph_name,
            "costmodel": self.costmodel,
            "accelerator": self.accelerator,
            "capacity_words": self.capacity_words,
            "capacity_how": self.capacity_how,
            "edges": [v.to_dict() for v in self.verdicts],
            "regions": [r.to_dict() for r in self.regions],
            "summary": self.summary(),
        }

    def describe(self) -> str:
        """The ``repro analyze`` table: per-edge verdicts, regions,
        genome-length reduction, exact/GA search-space sizes."""
        lines: List[str] = []
        lines.append(f"spacemap: {self.graph_name} on {self.accelerator} "
                     f"(costmodel {self.costmodel})")
        lines.append(f"capacity: {self.capacity_how}")
        w = max((len(f"{v.producer} -> {v.consumer}")
                 for v in self.verdicts), default=10)
        lines.append(f"  {'bit':>3}  {'edge':<{w}}  {'verdict':<10}  "
                     f"{'footprint_lb':>12}  {'saving_ub':>10}")
        for v in self.verdicts:
            lines.append(
                f"  {v.index:>3}  "
                f"{v.producer + ' -> ' + v.consumer:<{w}}  "
                f"{v.verdict:<10}  {v.footprint_lb_words:>12}  "
                f"{v.saving_ub_words:>10}")
        n = len(self.verdicts)
        lines.append(
            f"edges: {n} total — {len(self.forced_off)} forced_off, "
            f"{len(self.free)} free, {len(self.undecided)} undecided")
        lines.append(
            f"genome: {self.n_edges} -> {self.genome_length} bits "
            f"({len(self.frozen_indices)} frozen)")
        lines.append(f"regions: {len(self.regions)} independent")
        for r in self.regions:
            span = f"{r.nodes[0]} .. {r.nodes[-1]}" if len(r.nodes) > 1 \
                else r.nodes[0]
            lines.append(f"  region {r.index}: nodes [{r.lo}..{r.hi}] "
                         f"({span}), {len(r.edge_indices)} free bits, "
                         f"2^{len(r.edge_indices)} states")
        lines.append(
            f"search space: 2^{self.n_edges} raw = {self.raw_space_size()}"
            f" -> 2^{self.genome_length} masked = "
            f"{self.masked_space_size()} -> {self.factorized_states()} "
            f"states enumerated per-region (largest region "
            f"{self.largest_region_size()})")
        return "\n".join(lines)


# ---- the static classifier -------------------------------------------------------


def _rows_in_clamped(view: _GraphView, i: int, rows_out: int) -> int:
    """Input rows node ``i``'s layer needs for ``rows_out`` output rows,
    via the verifier's recurrence (already clamps to full height)."""
    return view._rows_in(view.layers[i], rows_out)


def edge_footprint_lb(view: _GraphView, bit: int) -> int:
    """Sound lower bound (words) on the t=1 footprint of **any** group
    containing fused edge ``bit`` = ``(u, v)``.

    Three nonnegative contributions every containing group pays:

    * ``v`` holds at least one output row (``rows[v] >= 1``);
    * ``u`` holds at least ``v``'s one-row input window — ``v`` is always
      an in-group consumer of ``u``, and the recurrence's ``need`` is a
      max over in-group consumers, so ``rows[u] >= min(rows_in(v, 1),
      p_u)`` whatever else the group contains;
    * any predecessor ``p`` of ``u`` whose *only* graph consumer is ``u``
      is either an in-group member (held at >= ``u``'s window) or an
      external input staged at exactly ``u``'s window (``u`` is then its
      first — only — in-group consumer), so its window contribution is
      mandatory either way.

    Deeper ancestors are *not* counted: a node outside the group with its
    consumer also outside contributes nothing, so only the first
    off-group hop is guaranteed.  The bound is therefore conservative —
    exactly what freezing a gene requires.
    """
    u, v = view.edges[bit]
    lu, lv = view.layers[u], view.layers[v]
    total = 0
    if lv.output_size:
        total += lv.m * lv.q * min(1, lv.p or 1)
    rin_v = _rows_in_clamped(view, v, 1)
    ru = min(rin_v, lu.p) if lu.p else rin_v
    if lu.output_size:
        total += lu.m * lu.q * ru
    win_u = _rows_in_clamped(view, u, ru)
    for p in view.preds[u]:
        lp = view.layers[p]
        if view.succs[p] == [u] and lp.output_size:
            total += lp.m * lp.q * min(win_u, lp.p or win_u)
    return total


def _region_footprint_ub(view: _GraphView, nodes: List[int]) -> int:
    """Upper bound (words) on the t=1 footprint of any group formed
    inside ``nodes``: every member holds at most its full output map and
    every staged external input at most its producer's full map."""
    nset = set(nodes)
    total = 0
    staged = set()
    for i in nodes:
        li = view.layers[i]
        if li.output_size:
            total += li.m * li.q * li.p
        for p in view.preds[i]:
            if p in nset or p in staged:
                continue
            staged.add(p)
            lp = view.layers[p]
            if lp.output_size:
                total += lp.m * lp.q * lp.p
    return total


def edge_saving_ub(view: _GraphView, bit: int) -> int:
    """Upper bound on DRAM words fusing edge ``(u, v)`` can save: the
    producer's boundary tensor stops crossing DRAM (one write plus one
    read per consumer); an ``input`` placeholder's tensor saves the
    consumer's staged read instead."""
    u, v = view.edges[bit]
    lu = view.layers[u]
    if view.costed(u):
        if not lu.output_size:
            return 0
        return lu.output_size * (1 + len(view.succs[u]))
    return view.layers[v].input_size


def _cut_positions(view: _GraphView, fusable: List[int]) -> List[int]:
    """Positions ``p`` (between node ``p-1`` and ``p``) no fusable edge
    spans: ``0`` and ``n`` are always cuts; interior cuts are where every
    crossing edge is frozen (or absent), so no group can straddle them."""
    crossed = [False] * (view.n + 1)
    for i in fusable:
        u, v = view.edges[i]
        for p in range(u + 1, v + 1):
            crossed[p] = True
    return [p for p in range(view.n + 1)
            if p == 0 or p == view.n or not crossed[p]]


def build_spacemap(graph: LayerGraph, costmodel: str = "default",
                   accelerator: str = "simba") -> SpaceMap:
    """Derive the :class:`SpaceMap` for ``graph`` on ``accelerator``
    under ``costmodel``'s capacity rule.

    Unknown costmodels (no static capacity semantics) degrade safely:
    nothing is frozen, nothing is ``free``, and the whole graph is one
    region — the map is then a no-op for search.
    """
    view = _GraphView(graph)
    cap, cap_how = _act_capacity(costmodel, accelerator)

    verdicts: List[EdgeVerdict] = []
    for bit, (u, v) in enumerate(view.edges):
        lb = 0
        saving = edge_saving_ub(view, bit)
        verdict = "undecided"
        if cap is not None:
            # only a pair of MAC-carrying endpoints makes every containing
            # group "multi" (hence footprint-checked by both cost models);
            # otherwise the bare pair itself is legal and nothing freezes
            if view.layers[u].macs and view.layers[v].macs:
                lb = edge_footprint_lb(view, bit)
                if lb > cap:
                    verdict = "forced_off"
        verdicts.append(EdgeVerdict(
            index=bit, producer=view.names[u], consumer=view.names[v],
            verdict=verdict, footprint_lb_words=lb, saving_ub_words=saving))

    fusable = [v.index for v in verdicts if v.verdict != "forced_off"]
    cuts = _cut_positions(view, fusable)
    regions: List[Region] = []
    for ri in range(len(cuts) - 1):
        lo, hi = cuts[ri], cuts[ri + 1] - 1
        edge_idx = tuple(i for i in fusable
                         if lo <= view.edges[i][0] and view.edges[i][1] <= hi)
        regions.append(Region(
            index=ri, lo=lo, hi=hi,
            nodes=tuple(view.names[lo:hi + 1]), edge_indices=edge_idx))

    # "free": capacity can never bite anywhere in the edge's region (the
    # maximal group there fits) and fusing has a positive saving bound
    if cap is not None:
        region_of: Dict[int, Region] = {}
        for r in regions:
            for i in r.edge_indices:
                region_of[i] = r
        ub_cache: Dict[int, int] = {}
        for k, v in enumerate(verdicts):
            if v.verdict != "undecided":
                continue
            r = region_of[v.index]
            if r.index not in ub_cache:
                ub_cache[r.index] = _region_footprint_ub(
                    view, list(range(r.lo, r.hi + 1)))
            if ub_cache[r.index] <= cap and v.saving_ub_words > 0:
                verdicts[k] = EdgeVerdict(
                    index=v.index, producer=v.producer, consumer=v.consumer,
                    verdict="free",
                    footprint_lb_words=v.footprint_lb_words,
                    saving_ub_words=v.saving_ub_words)

    return SpaceMap(
        graph_name=graph.name, costmodel=costmodel, accelerator=accelerator,
        n_edges=view.m, capacity_words=cap, capacity_how=cap_how,
        verdicts=verdicts, regions=regions)
