"""Independent artifact verification (no ``core.fusion``, no evaluator).

A :class:`~repro.search.artifact.ScheduleArtifact` asserts: *this genome,
on this graph, forms these groups, is schedulable, fits the machine, and
costs this much*.  Every one of those claims came from the same engine
that searched it.  This module re-checks them from the artifact's bytes
alone — the embedded :class:`~repro.ir.GraphIR` (or a registry rebuild)
plus the edge-bitmask genome — with its own adjacency reconstruction,
its own union-find grouping, its own Kahn condensation check, and its
own line-buffer footprint recurrence.  Deliberately, nothing here
imports ``repro.core.fusion`` or ``repro.costmodel.evaluator``: an
artifact-corrupting bug (or a hand-edited store object) in the engine
path cannot also hide the evidence in the checker path
(``tests/test_analysis_verify.py`` pins the no-import rule).

Checks, in order (each becomes a :class:`Check` row in the report):

==================  =========================================================
graph-source        embedded IR parses / registry workload rebuilds
fingerprint         ``ir1:sha256`` of the canonical IR matches the artifact
                    (legacy ``sha256:`` fingerprints get a distinct message)
edges               ``n_edges`` and genome range match the re-derived edge
                    list (same dedupe + order as ``CompiledGraph``)
fused-edges         the stored edge list is exactly the decoded genome
groups              union-find group count matches ``best.n_groups`` /
                    ``baseline.n_groups``
schedulable         group condensation is acyclic (own Kahn scan)
footprint           every multi-layer group's t=1 line-buffer window fits
                    the machine's activation level
act-writes          per-tensor DRAM write events re-derived from group
                    boundaries match both cost records
cost-consistency    per-group breakdowns cover the derived groups and sum
                    to the claimed ``best`` totals
spacemap            (``spacemap=True`` runs) the stored static-analysis
                    summary matches an independent re-derivation
                    (:mod:`repro.analysis.spacemap`) and the genome sets
                    no provably forced-off gene
store-key           (``--store`` only) the object's content-address matches
bounds              modeled traffic >= Chen-et-al lower bounds
                    (:mod:`repro.analysis.bounds`) — yields the certificate
==================  =========================================================

The surviving artifact carries a :class:`Certificate`: its DRAM traffic,
the schedule-specific lower bound, the schedule-independent graph lower
bound, and the optimality gaps against both — rendered by ``repro
verify`` and ``repro report``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Any, Dict, List, Optional, Sequence,
                    Tuple)

from repro.analysis.bounds import (TrafficBound, graph_bound,
                                   onchip_words_for, schedule_bound)
from repro.core.graph import Layer, LayerGraph

if TYPE_CHECKING:                    # type-only: keeps the runtime import
    from repro.search.artifact import ScheduleArtifact    # graph light

#: relative tolerance for float totals (energy, cycles): the artifact's
#: ``best`` was summed from the identical per-group tuples in the identical
#: order, so the match is exact in practice; the tolerance only forgives a
#: serializer that round-trips floats through shortest-repr decimal
_REL_TOL = 1e-9


@dataclass(frozen=True)
class Check:
    """One verified claim: name, verdict, human-readable evidence."""

    name: str
    ok: bool
    detail: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "ok": self.ok, "detail": self.detail}


@dataclass(frozen=True)
class Certificate:
    """Optimality-gap certificate: modeled DRAM traffic vs the Chen et al.
    lower bounds (see :mod:`repro.analysis.bounds`)."""

    traffic_words: int            # best.dram_read + best.dram_write
    schedule_lb_words: int        # sum of per-group bounds for THIS grouping
    graph_lb_words: int           # bound no grouping can beat
    onchip_words: int             # S the Hong-Kung term pebbled against
    group_lb_words: Tuple[int, ...] = ()

    @property
    def gap_vs_schedule(self) -> float:
        """Fractional slack above this schedule's own bound (>= 0)."""
        if self.schedule_lb_words <= 0:
            return 0.0
        return self.traffic_words / self.schedule_lb_words - 1.0

    @property
    def gap_vs_graph(self) -> float:
        """Fractional distance from provable optimality: how far the
        winner's traffic sits above what *any* grouping must pay."""
        if self.graph_lb_words <= 0:
            return 0.0
        return self.traffic_words / self.graph_lb_words - 1.0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "traffic_words": self.traffic_words,
            "schedule_lb_words": self.schedule_lb_words,
            "graph_lb_words": self.graph_lb_words,
            "onchip_words": self.onchip_words,
            "gap_vs_schedule": self.gap_vs_schedule,
            "gap_vs_graph": self.gap_vs_graph,
            "group_lb_words": list(self.group_lb_words),
        }

    def describe(self) -> str:
        return (f"DRAM traffic {self.traffic_words} words >= schedule LB "
                f"{self.schedule_lb_words} (gap {self.gap_vs_schedule:+.1%})"
                f" >= graph LB {self.graph_lb_words} "
                f"(gap {self.gap_vs_graph:+.1%})")


@dataclass
class VerificationReport:
    """Outcome of :func:`verify_artifact`: the check rows plus, when every
    structural check passed and the cost model has a bound model, the
    lower-bound :class:`Certificate`."""

    checks: List[Check] = field(default_factory=list)
    certificate: Optional[Certificate] = None

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.checks)

    def failures(self) -> List[Check]:
        return [c for c in self.checks if not c.ok]

    def check(self, name: str) -> Optional[Check]:
        for c in self.checks:
            if c.name == name:
                return c
        return None

    def to_dict(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "checks": [c.to_dict() for c in self.checks],
            "certificate": self.certificate.to_dict()
                           if self.certificate else None,
        }

    def describe(self) -> str:
        lines = []
        for c in self.checks:
            mark = "ok  " if c.ok else "FAIL"
            lines.append(f"  [{mark}] {c.name}"
                         + (f": {c.detail}" if c.detail else ""))
        if self.certificate is not None:
            lines.append(f"  certificate: {self.certificate.describe()}")
        return "\n".join(lines)


# ---- independent structural view ------------------------------------------------


class _GraphView:
    """The verifier's own integer view of the searched graph.

    Rebuilds successor lists from each node's predecessor list (one entry
    per occurrence, consumers in node order) and dedupes parallel edges
    first-occurrence-first — the same construction, re-derived, that fixes
    the genome's bit order in ``repro.core.graph.CompiledGraph``.  All
    grouping/legality math below runs on these arrays only.
    """

    def __init__(self, graph: LayerGraph):
        self.names: Tuple[str, ...] = tuple(graph.layers)
        self.n = len(self.names)
        self.id_of = {nm: i for i, nm in enumerate(self.names)}
        self.layers: Tuple[Layer, ...] = tuple(
            graph.layers[nm] for nm in self.names)
        self.preds: List[List[int]] = [
            [self.id_of[p] for p in graph.preds(nm)] for nm in self.names]
        succs: List[List[int]] = [[] for _ in range(self.n)]
        for v in range(self.n):
            for u in self.preds[v]:
                succs[u].append(v)
        self.succs = succs
        # parallel-edge dedupe, successor-major order (= genome bit order)
        self.edges: List[Tuple[int, int]] = list(dict.fromkeys(
            (u, v) for u in range(self.n) for v in succs[u]))
        self.m = len(self.edges)

    # ---- grouping ---------------------------------------------------------------
    def groups_of(self, mask: int) -> List[List[int]]:
        """Weakly-connected components over the fused edges, by union-find;
        groups ordered by smallest member id, members ascending."""
        parent = list(range(self.n))

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for i, (u, v) in enumerate(self.edges):
            if (mask >> i) & 1:
                ru, rv = find(u), find(v)
                if ru != rv:
                    parent[max(ru, rv)] = min(ru, rv)
        by_root: Dict[int, List[int]] = {}
        for x in range(self.n):
            by_root.setdefault(find(x), []).append(x)
        return [by_root[r] for r in sorted(by_root)]

    def condensation_acyclic(self, groups: Sequence[Sequence[int]]) -> bool:
        """Own Kahn scan over the group condensation: the fused schedule is
        executable iff no inter-group dependency cycle exists."""
        comp = [0] * self.n
        for gi, members in enumerate(groups):
            for x in members:
                comp[x] = gi
        k = len(groups)
        gsucc: List[List[int]] = [[] for _ in range(k)]
        indeg = [0] * k
        for u in range(self.n):
            for v in self.succs[u]:
                if comp[u] != comp[v]:      # parallel edges inflate both
                    gsucc[comp[u]].append(comp[v])
                    indeg[comp[v]] += 1     # sides symmetrically: exact
        stack = [g for g in range(k) if indeg[g] == 0]
        seen = 0
        while stack:
            g = stack.pop()
            seen += 1
            for h in gsucc[g]:
                indeg[h] -= 1
                if indeg[h] == 0:
                    stack.append(h)
        return seen == k

    # ---- boundary / cost structure ----------------------------------------------
    def costed(self, i: int) -> bool:
        layer = self.layers[i]
        return not (layer.macs == 0 and layer.kind == "input")

    def outputs_offchip(self, i: int, members: Sequence[int]) -> bool:
        mset = set(members)
        succ = self.succs[i]
        return (not succ) or any(v not in mset for v in succ)

    def act_write_events(self, groups: Sequence[Sequence[int]]) -> int:
        events = 0
        for members in groups:
            for i in members:
                if self.costed(i) and self.layers[i].output_size \
                        and self.outputs_offchip(i, members):
                    events += 1
        return events

    # ---- footprint (own line-buffer recurrence) ----------------------------------
    def member_topo(self, members: Sequence[int]) -> List[int]:
        """FIFO-Kahn order of the induced subgraph, seeded ascending — the
        same ready-queue discipline the engine's member ordering uses, so
        the first-consumer staging rule below picks the same consumer."""
        mset = set(members)
        indeg = {i: sum(1 for p in self.preds[i] if p in mset)
                 for i in members}
        ready = [i for i in sorted(members) if indeg[i] == 0]
        order: List[int] = []
        while ready:
            u = ready.pop(0)
            order.append(u)
            for v in self.succs[u]:
                if v in mset:
                    indeg[v] -= 1
                    if indeg[v] == 0:
                        ready.append(v)
        return order

    @staticmethod
    def _rows_in(layer: Layer, rows_out: int) -> int:
        """Input rows needed for ``rows_out`` output rows (receptive-field
        recurrence, re-derived; clamps mirror the full-height limits)."""
        rows_out = min(rows_out, layer.p) if layer.p else rows_out
        if layer.kind in ("conv", "dwconv", "pool"):
            need = (rows_out - 1) * layer.stride[0] \
                + (layer.r - 1) * layer.dilation[0] + 1
            return min(max(need, 1), layer.h) if layer.h else need
        if layer.kind in ("fc", "global_pool"):
            return layer.h if layer.h else 1
        if layer.kind == "upsample":
            return min(max(math.ceil(
                rows_out * max(layer.h, 1) / max(layer.p, 1)), 1),
                max(layer.h, 1))
        return rows_out                     # elementwise glue: row-for-row

    def footprint_words(self, members: Sequence[int], t: int = 1) -> int:
        """Activation words live while streaming ``t`` sink rows: each
        member keeps its backtraced window; external inputs are staged at
        the window of their first in-group consumer."""
        order = self.member_topo(members)
        mset = set(order)
        rows: Dict[int, int] = {}
        for i in reversed(order):
            layer = self.layers[i]
            inner = [v for v in self.succs[i] if v in mset]
            if not inner:
                rows[i] = min(t, layer.p) if layer.p else t
            else:
                need = 1
                for v in inner:
                    need = max(need, self._rows_in(self.layers[v], rows[v]))
                rows[i] = min(need, layer.p) if layer.p else need
        total = 0
        staged = set()
        for i in order:
            layer = self.layers[i]
            if layer.output_size:
                total += layer.m * layer.q \
                    * min(rows[i], layer.p or rows[i])
            for src in self.preds[i]:
                if src in mset or src in staged:
                    continue
                staged.add(src)
                src_l = self.layers[src]
                if not src_l.output_size:
                    continue
                win = self._rows_in(layer, rows[i])
                total += src_l.m * src_l.q * min(win, src_l.p or win)
        return total

    def is_multi(self, members: Sequence[int]) -> bool:
        """Groups the engine tiles (and footprint-checks): more than one
        MAC-carrying member."""
        return len(members) > 1 and \
            sum(1 for i in members if self.layers[i].macs) > 1


# ---- capacity resolution ---------------------------------------------------------


def _act_capacity(costmodel: str, accelerator: str
                  ) -> Tuple[Optional[int], str]:
    """(activation-level words the footprint must fit, provenance) — or
    (None, reason) when this cost backend's capacity rule is unknown."""
    if costmodel == "default":
        from repro.search.registry import RegistryError, build_accelerator
        try:
            acc = build_accelerator(accelerator)
        except RegistryError as e:
            return None, f"unknown accelerator {accelerator!r}: {e}"
        return acc.act_buf_words, \
            f"{accelerator} act_buf ({acc.act_buf_words} words)"
    if costmodel == "tpu":
        from repro.costmodel.tpu_fusion import VMEM_BYTES
        words = int(VMEM_BYTES / 2) // 2
        return words, f"TPU VMEM activation budget ({words} words)"
    return None, f"no capacity rule for costmodel {costmodel!r}"


# ---- the verifier ----------------------------------------------------------------


def _rebuild(artifact: "ScheduleArtifact"
             ) -> Tuple[Optional[LayerGraph], Optional[str], Check]:
    """(graph, recomputed fingerprint, graph-source check).

    Prefers the embedded GraphIR (self-contained artifacts); registry
    workloads rebuild from their spec — the fingerprint check then proves
    the registry still builds the structure the genome indexes."""
    from repro.ir import GraphIR, IRError
    spec = artifact.spec
    if artifact.graph_ir is not None:
        try:
            ir = GraphIR.from_dict(artifact.graph_ir)
            return ir.build(), ir.fingerprint(), \
                Check("graph-source", True, "embedded GraphIR")
        except (IRError, ValueError, KeyError, TypeError) as e:
            return None, None, Check(
                "graph-source", False,
                f"embedded GraphIR does not parse/build: {e}")
    if spec.workload.startswith("ir:"):
        return None, None, Check(
            "graph-source", False,
            f"workload {spec.workload!r} requires an embedded graph_ir "
            f"but the artifact carries none (stripped or legacy writer)")
    from repro.search.registry import RegistryError
    from repro.search.registry import build_workload
    try:
        graph = build_workload(spec.workload, **spec.workload_kwargs)
    except (RegistryError, IRError, ValueError, TypeError,
            FileNotFoundError) as e:
        return None, None, Check(
            "graph-source", False,
            f"cannot rebuild workload {spec.workload!r}: {e}")
    return graph, GraphIR.from_graph(graph).fingerprint(), \
        Check("graph-source", True, f"registry rebuild of {spec.workload!r}")


def _check_fingerprint(artifact: "ScheduleArtifact", fp: str) -> Check:
    from repro.ir import GraphIR
    claimed = artifact.graph_fingerprint
    if claimed == fp:
        return Check("fingerprint", True, fp)
    fmt = GraphIR.FINGERPRINT_FORMAT + ":"
    if not claimed.startswith(fmt):
        return Check(
            "fingerprint", False,
            f"artifact carries a {claimed.split(':', 1)[0]!r}-format "
            f"fingerprint; this build computes {fmt[:-1]!r} — the genome "
            f"cannot be safely re-bound, regenerate the artifact")
    return Check("fingerprint", False,
                 f"claimed {claimed} but the graph hashes to {fp} "
                 f"(IR bytes and genome disagree)")


def _check_cost_consistency(artifact: "ScheduleArtifact", view: _GraphView,
                            groups: List[List[int]]) -> Check:
    bds = artifact.group_breakdowns
    if not bds:
        return Check("cost-consistency", True,
                     "skipped: artifact embeds no per-group breakdowns")
    if len(bds) != len(groups):
        return Check("cost-consistency", False,
                     f"{len(bds)} breakdown rows for "
                     f"{len(groups)} derived groups")
    for gi, (bd, members) in enumerate(zip(bds, groups)):
        want = {view.names[i] for i in members}
        got = set(bd.members)
        if got and got != want:
            return Check(
                "cost-consistency", False,
                f"breakdown row {gi} covers {sorted(got)} but the genome "
                f"derives group {sorted(want)}")
    sums = {
        "dram_read_words": sum(b.dram_read_words for b in bds),
        "dram_write_words": sum(b.dram_write_words for b in bds),
        "act_write_events": sum(b.act_write_events for b in bds),
        "macs": sum(b.macs for b in bds),
    }
    for name, got in sums.items():
        want = getattr(artifact.best, name)
        if got != want:
            return Check("cost-consistency", False,
                         f"breakdowns sum {name}={got} but best claims "
                         f"{want}")
    for name, got in (("energy_pj", sum(b.energy_pj for b in bds)),
                      ("cycles", sum(b.cycles for b in bds))):
        want = getattr(artifact.best, name)
        scale = max(abs(want), abs(got), 1.0)
        if abs(got - want) > _REL_TOL * scale:
            return Check("cost-consistency", False,
                         f"breakdowns sum {name}={got!r} but best claims "
                         f"{want!r}")
    return Check("cost-consistency", True,
                 f"{len(bds)} group breakdowns sum to the claimed totals")


def _check_spacemap(artifact: "ScheduleArtifact", graph: LayerGraph,
                    mask: int) -> Check:
    """Re-derive the static fusion-space analysis and hold the artifact to
    it: the stored summary must match the independent re-derivation and
    the winning genome must not set any provably forced-off gene."""
    # lazy: spacemap imports this module's _GraphView, so a top-level
    # import here would be circular
    from repro.analysis.spacemap import build_spacemap
    claimed = artifact.spacemap
    if claimed is None:
        return Check(
            "spacemap", False,
            "spec ran with spacemap=True but the artifact carries no "
            "spacemap summary (stripped or written by a legacy build)")
    sm = build_spacemap(graph, artifact.spec.costmodel,
                        artifact.spec.accelerator)
    derived = sm.summary()
    if derived != claimed:
        diff = sorted(k for k in set(derived) | set(claimed)
                      if derived.get(k) != claimed.get(k))
        return Check(
            "spacemap", False,
            f"stored spacemap summary disagrees with the re-derived "
            f"analysis on {diff} (e.g. {diff[0]!r}: stored "
            f"{claimed.get(diff[0])!r}, derived {derived.get(diff[0])!r})")
    hot = [i for i in sm.frozen_indices if (mask >> i) & 1]
    if hot:
        return Check(
            "spacemap", False,
            f"genome sets statically forced-off gene bits {hot} — every "
            f"grouping containing those edges exceeds the activation "
            f"capacity, so the claimed schedule cannot be valid")
    return Check(
        "spacemap", True,
        f"{len(sm.frozen_indices)} frozen genes and {len(sm.regions)} "
        f"regions re-derived identically; genome respects the freeze")


def verify_artifact(artifact: "ScheduleArtifact", *,
                    expect_key: Optional[str] = None,
                    obs: Optional[Any] = None
                    ) -> VerificationReport:
    """Re-derive and re-check every claim a :class:`ScheduleArtifact`
    makes (see module docstring for the check list).  ``expect_key``
    additionally pins the artifact to a store object's content address.
    ``obs`` (duck-typed: anything with ``record_certificate``, e.g. a
    :class:`repro.obs.TelemetryCollector`) receives the traffic certificate
    when one is derived — kept duck-typed so this module's import boundary
    (engine-free) needs no new pins."""
    report = VerificationReport()
    checks = report.checks

    graph, fp, src_check = _rebuild(artifact)
    checks.append(src_check)
    if graph is None or fp is None:
        return report
    checks.append(_check_fingerprint(artifact, fp))

    view = _GraphView(graph)
    mask = artifact.genome_mask
    edge_ok = artifact.n_edges == view.m and 0 <= mask < (1 << view.m)
    checks.append(Check(
        "edges", edge_ok,
        f"{view.m} edges re-derived, genome {mask:#x}" if edge_ok else
        f"artifact claims n_edges={artifact.n_edges}, genome {mask:#x}; "
        f"the graph re-derives {view.m} edges "
        f"(genome must lie in [0, 2**{view.m}))"))
    if not edge_ok:
        return report

    decoded = sorted([view.names[u], view.names[v]]
                     for i, (u, v) in enumerate(view.edges)
                     if (mask >> i) & 1)
    stored = sorted(list(e) for e in artifact.fused_edges)
    checks.append(Check(
        "fused-edges", decoded == stored,
        f"{len(decoded)} fused edges match the genome" if decoded == stored
        else f"stored fused_edges disagree with the decoded genome "
             f"(stored {len(stored)}, decoded {len(decoded)}; first "
             f"diff {next((a for a, b in zip(stored, decoded) if a != b), (stored or decoded)[:1])})"))

    groups = view.groups_of(mask)
    n_ok = artifact.best.n_groups == len(groups) \
        and artifact.baseline.n_groups == view.n
    checks.append(Check(
        "groups", n_ok,
        f"{len(groups)} fused groups over {view.n} layers" if n_ok else
        f"derived {len(groups)} groups / {view.n} layers but artifact "
        f"claims best.n_groups={artifact.best.n_groups}, "
        f"baseline.n_groups={artifact.baseline.n_groups}"))

    acyclic = view.condensation_acyclic(groups)
    checks.append(Check(
        "schedulable", acyclic,
        "group condensation is acyclic (Kahn)" if acyclic else
        "group condensation contains a dependency cycle — this genome is "
        "not executable and should never have been packaged"))

    cap, cap_how = _act_capacity(artifact.spec.costmodel,
                                 artifact.spec.accelerator)
    if cap is None:
        checks.append(Check("footprint", True, f"skipped: {cap_how}"))
    else:
        over = []
        for members in groups:
            if not view.is_multi(members):
                continue
            fw = view.footprint_words(members, 1)
            if fw > cap:
                over.append((members, fw))
        checks.append(Check(
            "footprint", not over,
            f"all multi-layer groups fit {cap_how}" if not over else
            f"group {[view.names[i] for i in over[0][0]]} needs "
            f"{over[0][1]} activation words at t=1 but {cap_how} — "
            f"over-capacity groups are invalid mappings"))

    best_aw = view.act_write_events(groups)
    base_aw = view.act_write_events([[i] for i in range(view.n)])
    aw_ok = best_aw == artifact.best.act_write_events \
        and base_aw == artifact.baseline.act_write_events
    checks.append(Check(
        "act-writes", aw_ok,
        f"DRAM act-writes {base_aw} -> {best_aw}" if aw_ok else
        f"re-derived act-writes base={base_aw}, best={best_aw} but "
        f"artifact claims base={artifact.baseline.act_write_events}, "
        f"best={artifact.best.act_write_events}"))

    checks.append(_check_cost_consistency(artifact, view, groups))

    if artifact.spacemap is not None or artifact.spec.spacemap:
        checks.append(_check_spacemap(artifact, graph, mask))

    if expect_key is not None:
        from repro.serve.store import artifact_key
        key = artifact_key(artifact.graph_fingerprint, artifact.spec)
        checks.append(Check(
            "store-key", key == expect_key,
            "content address matches" if key == expect_key else
            f"object stored under {expect_key[:12]}... but its content "
            f"addresses to {key[:12]}..."))

    onchip = None
    if cap is not None:                    # known costmodel semantics only
        from repro.search.registry import RegistryError
        try:
            onchip = onchip_words_for(artifact.spec.costmodel,
                                      artifact.spec.accelerator)
        except RegistryError:
            onchip = None
    if onchip is None:
        checks.append(Check(
            "bounds", True,
            f"skipped: no lower-bound model for costmodel "
            f"{artifact.spec.costmodel!r}"))
        return report
    name_groups = [[view.names[i] for i in g] for g in groups]
    per_group, sched_lb = schedule_bound(graph, name_groups, onchip)
    g_lb: TrafficBound = graph_bound(graph, onchip)
    traffic = artifact.best.dram_read_words + artifact.best.dram_write_words
    cert = Certificate(
        traffic_words=traffic, schedule_lb_words=sched_lb,
        graph_lb_words=g_lb.words, onchip_words=onchip,
        group_lb_words=tuple(b.words for b in per_group))
    report.certificate = cert
    lb_ok = traffic >= sched_lb and traffic >= g_lb.words
    checks.append(Check(
        "bounds", lb_ok,
        cert.describe() if lb_ok else
        f"claimed DRAM traffic {traffic} words is BELOW the provable "
        f"lower bound (schedule LB {sched_lb}, graph LB {g_lb.words}) — "
        f"the reported cost is deflated or the genome was altered"))
    if obs is not None:
        obs.record_certificate(artifact.graph_fingerprint, cert, report.ok)
    return report


def verify_store(root: str, *, obs: Optional[Any] = None
                 ) -> List[Tuple[str, VerificationReport]]:
    """Verify every object in an :class:`~repro.serve.store.ArtifactStore`
    against its own content address.  Unreadable objects yield a report
    whose single failed ``store-object`` check carries the load error."""
    from repro.serve.store import ArtifactStore, StoreError
    store = ArtifactStore(root, create=False)
    out: List[Tuple[str, VerificationReport]] = []
    for key in store.keys():
        try:
            artifact = store.load_key(key)
        except StoreError as e:
            out.append((key, VerificationReport(
                checks=[Check("store-object", False, str(e))])))
            continue
        if artifact is None:               # raced with a concurrent delete
            continue
        out.append((key, verify_artifact(artifact, expect_key=key, obs=obs)))
    return out
