from repro.runtime.fault import (FaultConfig, FaultInjector, Watchdog,
                                 run_with_restarts)

__all__ = ["FaultConfig", "FaultInjector", "Watchdog", "run_with_restarts"]
