"""Fault-tolerance runtime: watchdog, bounded restarts, fault injection.

On a real pod the failure domains are: chip/host death (job restarts from
the latest checkpoint on spare capacity), stragglers (synchronous SPMD turns
them into global slowdowns — the watchdog flags steps exceeding the
deadline), and silent data corruption (checkpoint checksums).  This module
implements the *control plane* of that story in-process so it is testable:

* :func:`run_with_restarts` — supervises a step function; on a (possibly
  injected) failure it reloads the latest checkpoint and resumes, up to
  ``max_restarts``; the deterministic data pipeline guarantees no sample is
  replayed or skipped.
* :class:`Watchdog` — per-step deadline monitor (straggler mitigation: at
  scale you alert + evict; here we record and expose).
* :class:`FaultInjector` — deterministic failure schedule for tests/examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class FaultConfig:
    max_restarts: int = 3
    step_deadline_s: float = 60.0


class SimulatedFailure(RuntimeError):
    pass


class FaultInjector:
    """Raises :class:`SimulatedFailure` at the configured global steps."""

    def __init__(self, fail_at_steps: List[int]):
        self.fail_at = set(fail_at_steps)
        self.fired: List[int] = []

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.append(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class Watchdog:
    """Straggler detector: records step durations, flags deadline misses."""

    def __init__(self, deadline_s: float):
        self.deadline_s = deadline_s
        self.durations: List[float] = []
        self.violations: List[int] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int):
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self.durations.append(dt)
        if dt > self.deadline_s:
            self.violations.append(step)
        self._t0 = None
        return dt


def run_with_restarts(*, total_steps: int, init_state: Callable[[], Dict],
                      step_fn: Callable[[Dict, int], Dict],
                      save_fn: Callable[[Dict, int], None],
                      restore_fn: Callable[[], Optional[tuple]],
                      save_every: int = 10,
                      fault: FaultConfig = FaultConfig(),
                      injector: Optional[FaultInjector] = None) -> Dict:
    """Supervised training driver.

    ``restore_fn() -> (state, step) | None``; ``step_fn(state, step) ->
    state``.  Returns {"state", "restarts", "watchdog", "completed_steps"}.
    """
    watchdog = Watchdog(fault.step_deadline_s)
    restarts = 0
    while True:
        restored = restore_fn()
        if restored is None:
            state, start = init_state(), 0
        else:
            state, last_saved = restored
            start = last_saved + 1
        try:
            for step in range(start, total_steps):
                if injector is not None:
                    injector.check(step)
                watchdog.start()
                state = step_fn(state, step)
                watchdog.stop(step)
                if (step + 1) % save_every == 0 or step == total_steps - 1:
                    save_fn(state, step)
            return {"state": state, "restarts": restarts,
                    "watchdog": watchdog, "completed_steps": total_steps}
        except SimulatedFailure:
            restarts += 1
            if restarts > fault.max_restarts:
                raise
