from repro.workloads.base import (FunctionWorkload, GraphIRWorkload, Param,
                                  Workload, WorkloadParamError, as_workload)
from repro.workloads.cnn_zoo import (build_workload, mobilenet_v3_large,
                                     resnet50, unet, vgg16, WORKLOADS)

__all__ = ["FunctionWorkload", "GraphIRWorkload", "Param", "Workload",
           "WorkloadParamError", "as_workload", "build_workload",
           "mobilenet_v3_large", "resnet50", "unet", "vgg16", "WORKLOADS"]
