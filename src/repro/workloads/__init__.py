from repro.workloads.cnn_zoo import (build_workload, mobilenet_v3_large,
                                     resnet50, unet, vgg16, WORKLOADS)

__all__ = ["build_workload", "mobilenet_v3_large", "resnet50", "unet",
           "vgg16", "WORKLOADS"]
