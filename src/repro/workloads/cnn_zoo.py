"""CNN workloads evaluated in the paper: ResNet-50 [4], MobileNet-v3 [6],
U-Net [5]; VGG-16 is included because the paper uses it to size the fusion
state space (2^16, §III-A).  Batch = 1 (edge inference, §V).

All builders emit a :class:`repro.core.graph.LayerGraph` whose node insertion
order is topological.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

from repro.core.graph import Layer, LayerGraph


class _Builder:
    """Tracks the running activation shape while appending layers."""

    def __init__(self, name: str, c: int, h: int, w: int):
        self.g = LayerGraph(name)
        self.head = self.g.add(Layer(name="input", kind="input",
                                     m=c, p=h, q=w))
        self.c, self.h, self.w = c, h, w
        self._uid = 0

    def _name(self, base: str) -> str:
        self._uid += 1
        return f"{base}_{self._uid}"

    @staticmethod
    def _out_hw(h, w, r, s, stride, pad, dil=(1, 1)):
        p = (h + 2 * pad[0] - dil[0] * (r - 1) - 1) // stride[0] + 1
        q = (w + 2 * pad[1] - dil[1] * (s - 1) - 1) // stride[1] + 1
        return p, q

    def conv(self, m: int, k: int = 3, stride: int = 1,
             pad: Optional[int] = None, groups: int = 1,
             kind: str = "conv", base: str = "conv",
             src: Optional[str] = None) -> str:
        src = src or self.head
        pad = (k // 2) if pad is None else pad
        p, q = self._out_hw(self.h, self.w, k, k, (stride, stride), (pad, pad))
        lname = self.g.add(Layer(
            name=self._name(base), kind=kind, c=self.c, h=self.h, w=self.w,
            m=m, p=p, q=q, r=k, s=k, stride=(stride, stride),
            padding=(pad, pad), groups=groups), [src])
        self.head, self.c, self.h, self.w = lname, m, p, q
        return lname

    def dwconv(self, k: int, stride: int = 1) -> str:
        return self.conv(self.c, k=k, stride=stride, groups=self.c,
                         kind="dwconv", base="dw")

    def pool(self, k: int = 2, stride: Optional[int] = None, pad: int = 0) -> str:
        stride = stride or k
        p, q = self._out_hw(self.h, self.w, k, k, (stride, stride), (pad, pad))
        lname = self.g.add(Layer(
            name=self._name("pool"), kind="pool", c=self.c, h=self.h,
            w=self.w, m=self.c, p=p, q=q, r=k, s=k,
            stride=(stride, stride), padding=(pad, pad)), [self.head])
        self.head, self.h, self.w = lname, p, q
        return lname

    def global_pool(self) -> str:
        lname = self.g.add(Layer(
            name=self._name("gpool"), kind="global_pool", c=self.c, h=self.h,
            w=self.w, m=self.c, p=1, q=1, r=self.h, s=self.w), [self.head])
        self.head, self.h, self.w = lname, 1, 1
        return lname

    def fc(self, m: int, src: Optional[str] = None) -> str:
        src = src or self.head
        lname = self.g.add(Layer(
            name=self._name("fc"), kind="fc",
            c=self.c * self.h * self.w, h=1, w=1, m=m, p=1, q=1), [src])
        self.head, self.c, self.h, self.w = lname, m, 1, 1
        return lname

    def add_residual(self, a: str, b: str) -> str:
        lname = self.g.add(Layer(
            name=self._name("add"), kind="add", c=self.c, h=self.h, w=self.w,
            m=self.c, p=self.h, q=self.w), [a, b])
        self.head = lname
        return lname

    def mul(self, a: str, b: str) -> str:
        lname = self.g.add(Layer(
            name=self._name("mul"), kind="mul", c=self.c, h=self.h, w=self.w,
            m=self.c, p=self.h, q=self.w), [a, b])
        self.head = lname
        return lname

    def concat(self, a: str, b: str, channels: int) -> str:
        lname = self.g.add(Layer(
            name=self._name("cat"), kind="concat", c=channels, h=self.h,
            w=self.w, m=channels, p=self.h, q=self.w), [a, b])
        self.head, self.c = lname, channels
        return lname

    def upsample(self, scale: int = 2) -> str:
        p, q = self.h * scale, self.w * scale
        lname = self.g.add(Layer(
            name=self._name("up"), kind="upsample", c=self.c, h=self.h,
            w=self.w, m=self.c, p=p, q=q), [self.head])
        self.head, self.h, self.w = lname, p, q
        return lname

    def done(self) -> LayerGraph:
        self.g.validate()
        return self.g


# ---- ResNet-50 [He et al. 2015] ---------------------------------------------------

def resnet50(hw: int = 224) -> LayerGraph:
    b = _Builder("resnet50", 3, hw, hw)
    b.conv(64, k=7, stride=2)
    b.pool(k=3, stride=2, pad=1)
    cfg = [(64, 256, 3, 1), (128, 512, 4, 2), (256, 1024, 6, 2),
           (512, 2048, 3, 2)]
    for width, out_ch, blocks, first_stride in cfg:
        for i in range(blocks):
            stride = first_stride if i == 0 else 1
            skip_src = b.head
            skip_c, skip_h, skip_w = b.c, b.h, b.w
            b.conv(width, k=1, stride=1, base="red")
            b.conv(width, k=3, stride=stride)
            b.conv(out_ch, k=1, stride=1, base="exp")
            if i == 0:
                # projection shortcut
                main = b.head
                b.head, b.c, b.h, b.w = skip_src, skip_c, skip_h, skip_w
                short = b.conv(out_ch, k=1, stride=stride, base="short")
                b.head = main
                skip_src = short
            b.add_residual(b.head, skip_src)
    b.global_pool()
    b.fc(1000)
    return b.done()


# ---- MobileNet-v3-Large [Howard et al. 2019] ----------------------------------------

def _bneck(b: _Builder, k: int, exp: int, out: int, se: bool, stride: int):
    src = b.head
    src_c, src_h, src_w = b.c, b.h, b.w
    if exp != b.c:
        b.conv(exp, k=1, base="expand")
    b.dwconv(k, stride=stride)
    if se:
        dw_out = b.head
        dw_c, dw_h, dw_w = b.c, b.h, b.w
        b.global_pool()
        b.fc(max(exp // 4, 8))
        b.fc(exp)
        se_out = b.head
        b.head, b.c, b.h, b.w = dw_out, dw_c, dw_h, dw_w
        b.mul(dw_out, se_out)
    b.conv(out, k=1, base="project")
    if stride == 1 and src_c == out:
        b.add_residual(b.head, src)


def mobilenet_v3_large(hw: int = 224) -> LayerGraph:
    b = _Builder("mobilenet_v3", 3, hw, hw)
    b.conv(16, k=3, stride=2)
    specs = [
        (3, 16, 16, False, 1), (3, 64, 24, False, 2), (3, 72, 24, False, 1),
        (5, 72, 40, True, 2), (5, 120, 40, True, 1), (5, 120, 40, True, 1),
        (3, 240, 80, False, 2), (3, 200, 80, False, 1),
        (3, 184, 80, False, 1), (3, 184, 80, False, 1),
        (3, 480, 112, True, 1), (3, 672, 112, True, 1),
        (5, 672, 160, True, 2), (5, 960, 160, True, 1),
        (5, 960, 160, True, 1),
    ]
    for k, exp, out, se, stride in specs:
        _bneck(b, k, exp, out, se, stride)
    b.conv(960, k=1)
    b.global_pool()
    b.fc(1280)
    b.fc(1000)
    return b.done()


# ---- U-Net [Ronneberger et al. 2015], 'same'-padded variant -------------------------

def unet(hw: int = 256, base_ch: int = 64, depth: int = 4,
         in_ch: int = 1, out_ch: int = 2) -> LayerGraph:
    b = _Builder("unet", in_ch, hw, hw)
    skips: List[Tuple[str, int, int, int]] = []
    ch = base_ch
    for _ in range(depth):
        b.conv(ch, k=3)
        b.conv(ch, k=3)
        skips.append((b.head, b.c, b.h, b.w))
        b.pool(k=2)
        ch *= 2
    b.conv(ch, k=3)
    b.conv(ch, k=3)
    for (skip, sc, sh, sw) in reversed(skips):
        b.upsample(2)
        b.conv(b.c // 2, k=3, base="upconv")
        b.concat(b.head, skip, b.c + sc)
        b.conv(b.c // 2, k=3)
        b.conv(b.c, k=3)
    b.conv(out_ch, k=1, base="head")
    return b.done()


# ---- VGG-16 ---------------------------------------------------------------------------

def vgg16(hw: int = 224) -> LayerGraph:
    b = _Builder("vgg16", 3, hw, hw)
    for reps, ch in ((2, 64), (2, 128), (3, 256), (3, 512), (3, 512)):
        for _ in range(reps):
            b.conv(ch, k=3)
        b.pool(k=2)
    b.fc(4096)
    b.fc(4096)
    b.fc(1000)
    return b.done()


WORKLOADS = {
    "resnet50": resnet50,
    "mobilenet_v3": mobilenet_v3_large,
    "unet": unet,
    "vgg16": vgg16,
}


def build_workload(name: str, **kw) -> LayerGraph:
    return WORKLOADS[name](**kw)
