"""The parametric ``Workload`` protocol: what the workload registry holds.

A workload is no longer a bare ``(**kwargs) -> LayerGraph`` callable but
an object that *describes itself*: a typed parameter schema plus a
``build``.  That is what lets spec strings (``mobilenet_v3@hw=160``),
``repro list --json`` tooling, and helpful error messages exist without
each caller re-deriving a builder's signature.

    class MyWorkload(Workload):
        name = "my_cnn"
        def params(self): return {"hw": Param("hw", 224, "int")}
        def build(self, **kw): ...

Plain functions still register directly — :class:`FunctionWorkload`
derives the schema from the signature (defaults give the types), so the
zoo builders and third-party ``@register_workload`` functions need no
boilerplate.  :class:`GraphIRWorkload` adapts a fixed
:class:`repro.ir.GraphIR` document (the ``file:model.json`` spec form).
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from repro.core.graph import LayerGraph

_KINDS: Dict[type, str] = {int: "int", float: "float", bool: "bool",
                           str: "str"}
#: annotation spellings under PEP 563 (`from __future__ import
#: annotations` turns every annotation into its source string)
_KIND_NAMES = {"int": "int", "float": "float", "bool": "bool", "str": "str"}
_PARSERS: Dict[str, Callable[[str], Any]] = {
    "int": int, "float": float, "str": str,
    "bool": lambda s: {"true": True, "1": True, "yes": True,
                       "false": False, "0": False, "no": False}[s.lower()],
}


class WorkloadParamError(ValueError):
    """Unknown or untypeable workload parameter; the message carries the
    schema so the caller can self-correct."""


@dataclass(frozen=True)
class Param:
    """One workload parameter: name, default (None = required), and a
    coercion kind (``int`` / ``float`` / ``bool`` / ``str`` / ``any``)."""

    name: str
    default: Any = None
    kind: str = "any"
    required: bool = False

    def coerce(self, value: Any) -> Any:
        """Parse a spec-string value (``"160"`` -> 160) per the schema;
        already-typed values (JSON kwargs) pass through."""
        if not isinstance(value, str) or self.kind in ("str", "any"):
            return value
        try:
            return _PARSERS[self.kind](value)
        except (ValueError, KeyError):
            raise WorkloadParamError(
                f"cannot parse {value!r} as {self.kind} for param "
                f"{self.name!r}") from None

    def describe(self) -> str:
        return f"{self.name}={self.default!r} ({self.kind})" \
            if not self.required else f"{self.name}=<required> ({self.kind})"

    def to_dict(self) -> Dict[str, Any]:
        return {"default": self.default, "type": self.kind,
                "required": self.required}


class Workload:
    """Base protocol: subclasses set :attr:`name` and implement
    :meth:`params` / :meth:`_build`; :meth:`build` layers schema
    validation + value coercion on top."""

    name: str = "workload"

    def params(self) -> Dict[str, Param]:
        return {}

    def doc(self) -> str:
        return (inspect.getdoc(self) or "").split("\n")[0]

    def _build(self, **kwargs) -> LayerGraph:
        raise NotImplementedError

    # ---- public surface --------------------------------------------------------
    #: True when the builder also accepts params beyond the schema
    #: (a ``**kwargs`` signature); unknown names then pass through uncoerced
    open_schema: bool = False

    def build(self, **kwargs) -> LayerGraph:
        """Validate/coerce ``kwargs`` against the schema, then build."""
        schema = self.params()
        unknown = sorted(set(kwargs) - set(schema))
        if unknown and not self.open_schema:
            raise WorkloadParamError(
                f"unknown param(s) {unknown} for workload {self.name!r}; "
                f"{self.schema_hint()}")
        coerced = {k: schema[k].coerce(v) if k in schema else v
                   for k, v in kwargs.items()}
        missing = sorted(p.name for p in schema.values()
                         if p.required and p.name not in coerced)
        if missing:
            raise WorkloadParamError(
                f"workload {self.name!r} requires param(s) {missing}; "
                f"{self.schema_hint()}")
        return self._build(**coerced)

    def schema_hint(self) -> str:
        """One line a user can act on — the schema plus a copy-pasteable
        spec string (mirrors the exhaustive backend's ``limit=`` hint)."""
        schema = self.params()
        if not schema:
            return (f"workload {self.name!r} accepts arbitrary params "
                    f"(**kwargs builder)" if self.open_schema
                    else f"workload {self.name!r} takes no params")
        listing = ", ".join(p.describe() for p in schema.values())
        first = next(iter(schema.values()))
        ex_val = first.default if first.default is not None else 1
        return (f"schema: {listing}; e.g. --workload "
                f"'{self.name}@{first.name}={ex_val}' or "
                f"workload_kwargs={{\"{first.name}\": {ex_val!r}}}")

    def describe(self) -> Dict[str, Any]:
        """Machine-readable description (``repro list --json``)."""
        d = {"doc": self.doc(),
             "params": {k: p.to_dict() for k, p in self.params().items()}}
        if self.open_schema:
            d["open_schema"] = True
        return d


class FunctionWorkload(Workload):
    """A plain ``(**kwargs) -> LayerGraph`` builder, schema derived from
    its signature (annotation first, else the default's type)."""

    def __init__(self, name: str, fn: Callable[..., LayerGraph]):
        self.name = name
        self.fn = fn
        self._params: Dict[str, Param] = {}
        try:
            sig = inspect.signature(fn)
        except (TypeError, ValueError):
            sig = None
        if sig is None:
            self.open_schema = True      # unintrospectable: don't reject
        for pname, p in (sig.parameters.items() if sig else ()):
            if p.kind is inspect.Parameter.VAR_KEYWORD:
                self.open_schema = True  # **kwargs: extra params allowed
                continue
            if p.kind is inspect.Parameter.VAR_POSITIONAL:
                continue
            default = None if p.default is inspect.Parameter.empty \
                else p.default
            # PEP 563 (`from __future__ import annotations`) leaves the
            # annotation as the string "int" — resolve both spellings
            ann = p.annotation
            kind = _KINDS.get(ann) if isinstance(ann, type) else \
                _KIND_NAMES.get(ann.strip()) if isinstance(ann, str) \
                else None
            kind = kind or _KINDS.get(type(default), "any")
            self._params[pname] = Param(
                pname, default, kind,
                required=p.default is inspect.Parameter.empty)

    def params(self) -> Dict[str, Param]:
        return dict(self._params)

    def doc(self) -> str:
        return (inspect.getdoc(self.fn) or "").split("\n")[0]

    def _build(self, **kwargs) -> LayerGraph:
        return self.fn(**kwargs)


class GraphIRWorkload(Workload):
    """A fixed :class:`repro.ir.GraphIR` document (``file:`` specs and
    embedded-IR artifacts); parameterless by construction."""

    def __init__(self, ir, name: Optional[str] = None):
        self.ir = ir
        self.name = name or ir.name

    def doc(self) -> str:
        return f"GraphIR document ({len(self.ir.nodes)} nodes)"

    def _build(self, **kwargs) -> LayerGraph:
        return self.ir.build()


def as_workload(obj: Any, name: str) -> Workload:
    """Adapt a registry entry to the protocol: Workload instances pass
    through, Workload subclasses are instantiated, callables are wrapped."""
    if isinstance(obj, Workload):
        return obj
    if isinstance(obj, type) and issubclass(obj, Workload):
        return obj()
    if callable(obj):
        return FunctionWorkload(name, obj)
    raise TypeError(f"workload {name!r} is neither a Workload nor a "
                    f"callable: {type(obj).__name__}")
